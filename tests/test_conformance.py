"""Round-trip conformance suite (ISSUE 5 satellite).

Hardens the codec registry end to end:

  * **round trips** — encode→decode across every registered codec ×
    representative dtypes × shapes (0-d, 1-element, odd sizes, >4-D) ×
    the ``xla``/``pallas_interpret`` backends, via the same
    ``leaf_policy`` entry the checkpoint/serving layers use;
  * **portability** — streams are byte-identical across backends, and a
    stream written by either backend decodes on both;
  * **exactness** — lossless codecs restore bit-exact; lossy codecs stay
    inside their declared error contract;
  * **corruption** — truncated, bit-flipped, crc-mismatched, and
    index-tampered v1/v2 streams raise clean :class:`ContainerError`s
    from ``from_bytes``/lazy ``LazyChunks``/the aggregated reader — never
    a crash, never silently decoded garbage.

Designed to run in the ``scripts/check.sh fast`` tier: the case grid is
small enough to finish with plan-compile time included.
"""

import json

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import api
from repro.core.codecs import available_methods
from repro.core.container import Compressed, ContainerError
from conftest import smooth_field_3d

BACKENDS = ("xla", "pallas_interpret")

# method → (dtype, shape) grid.  Shapes stress the policy edges: 0-d,
# single element, odd/prime sizes, >4-D (flattened by leaf_policy).
CASES = [
    ("mgard", "float32", ()),
    ("mgard", "float32", (1,)),
    ("mgard", "float32", (17,)),
    ("mgard", "float32", (5, 7)),
    ("mgard", "float64", (2, 3, 4, 5, 2)),   # >4-D: policy flattens
    ("mgard-progressive", "float32", ()),
    ("mgard-progressive", "float32", (17,)),
    ("mgard-progressive", "float32", (5, 7)),
    ("mgard-progressive", "float64", (2, 3, 4, 5, 2)),
    ("zfp", "float32", (1,)),
    ("zfp", "float32", (33,)),               # ragged → padded 4³ blocks
    ("zfp", "float32", (6, 7, 8)),
    ("zfp", "float64", (513,)),              # cast + odd size
    ("huffman", "int32", (1,)),
    ("huffman", "int32", (2049,)),
    ("huffman", "uint16", (31, 9)),
    ("huffman-bytes", "uint8", ()),
    ("huffman-bytes", "int16", (257,)),
    ("huffman-bytes", "float32", (5, 11)),
    ("huffman-bytes", "float64", (129,)),    # 8-byte elems: host fallback
]


def _data(method: str, dtype: str, shape: tuple) -> np.ndarray:
    rng = np.random.default_rng(hash((method, dtype, shape)) % (1 << 32))
    dt = np.dtype(dtype)
    if dt.kind == "f":
        return (rng.normal(size=shape) * 3).astype(dt)
    if method == "huffman":
        # genuine small-alphabet keys (what leaf_policy routes here)
        return np.minimum(
            np.abs(rng.normal(0, 9, shape)).astype(np.int64), 120
        ).astype(dt)
    return rng.integers(np.iinfo(dt).min, np.iinfo(dt).max, shape).astype(dt)


def _roundtrip(arr: np.ndarray, method: str, backend: str,
               decode_backend: str | None = None) -> tuple[Compressed, np.ndarray]:
    """Policy-encode on ``backend``, decode on ``decode_backend``."""
    params = (
        {"error_bound": 1e-2}
        if method in ("mgard", "mgard-progressive")
        else {"rate": 24} if method == "zfp" else {}
    )
    x, pol_method, pol_params = api.leaf_policy(arr, method, params)
    spec = api.make_spec(x, pol_method, backend=backend, **pol_params)
    c = api.encode(spec, jnp.asarray(x))
    api.finish_leaf_meta(c, arr)
    out = api.restore_leaf(
        np.asarray(api.decode(c, backend=decode_backend or backend)), c
    )
    return c, out


def _check_contract(arr: np.ndarray, out: np.ndarray, method: str) -> None:
    assert out.shape == arr.shape and out.dtype == arr.dtype
    if method in ("huffman", "huffman-bytes"):
        np.testing.assert_array_equal(out, arr)     # lossless: bit-exact
    elif method in ("mgard", "mgard-progressive"):
        vrange = float(arr.max() - arr.min()) if arr.size else 0.0
        # constant data: relative-to-range is vacuous, and the bin schedule
        # falls back to the *absolute* bound (BinSchedule.host_apply) — so
        # that is the contract to hold the codec to
        bound = 1e-2 * vrange + 1e-6 if vrange > 0.0 else 1e-2 + 1e-6
        assert np.abs(out - arr).max(initial=0.0) <= bound
    else:  # zfp fixed-rate: high rate on bounded data ⇒ small error
        scale = max(float(np.abs(arr).max(initial=0.0)), 1e-6)
        assert np.abs(out - arr).max(initial=0.0) <= 1e-2 * scale


def test_all_registered_codecs_covered():
    """The grid exercises every registered codec (a new codec must join)."""
    assert set(m for m, _d, _s in CASES) == set(available_methods())


@pytest.mark.parametrize("method,dtype,shape", CASES,
                         ids=[f"{m}-{d}-{'x'.join(map(str, s)) or '0d'}"
                              for m, d, s in CASES])
def test_roundtrip_and_backend_byte_identity(method, dtype, shape):
    """Encode→decode honours the codec contract, streams are byte-identical
    across backends, and streams cross-decode between backends."""
    arr = _data(method, dtype, shape)
    streams, outs = {}, {}
    for b in BACKENDS:
        c, out = _roundtrip(arr, method, b)
        _check_contract(arr, out, method)
        streams[b], outs[b] = c.to_bytes(), out
    assert streams["xla"] == streams["pallas_interpret"], (
        "stream bytes differ across backends"
    )
    np.testing.assert_array_equal(outs["xla"], outs["pallas_interpret"])
    # cross-decode: a stream written under xla decodes under interpret
    _c, out_cross = _roundtrip(arr, method, "xla",
                               decode_backend="pallas_interpret")
    _check_contract(arr, out_cross, method)


@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from(["huffman", "huffman-bytes"]),
    # fixed size menu: plan compiles are the cost driver, data is free —
    # the property varies content/spread, not the compile cache
    st.sampled_from([1, 7, 1024, 2999]),
    st.integers(0, 200),
)
def test_lossless_roundtrip_property(method, n, spread):
    """Property: any int array round-trips bit-exact on both backends with
    byte-identical streams."""
    rng = np.random.default_rng(n * 1000 + spread)
    arr = rng.integers(0, spread + 1, n).astype(np.int32)
    blobs = []
    for b in BACKENDS:
        c, out = _roundtrip(arr, method, b)
        np.testing.assert_array_equal(out, arr)
        blobs.append(c.to_bytes())
    assert blobs[0] == blobs[1]


@pytest.mark.slow  # every error bound is a fresh plan compile (~16s total)
@settings(max_examples=4, deadline=None)
@given(st.sampled_from([4, 12, 40]), st.floats(1e-4, 1e-1))
def test_mgard_error_bound_property(n, eb):
    """Property: MGARD honours any requested relative error bound."""
    arr = smooth_field_3d(12)[:n].astype(np.float32)
    spec = api.make_spec(arr, "mgard", error_bound=float(eb), backend="xla")
    c = api.encode(spec, jnp.asarray(arr))
    out = np.asarray(api.decode(c))
    vrange = float(arr.max() - arr.min())
    assert np.abs(out - arr).max() <= float(eb) * vrange + 1e-7


# ---------------------------------------------------------------------------
# container corruption: loud ContainerErrors, never garbage
# ---------------------------------------------------------------------------


def _sample_container(version: int = 2) -> tuple[Compressed, bytes, np.ndarray]:
    rng = np.random.default_rng(7)
    keys = np.minimum(np.abs(rng.normal(0, 9, 4096)).astype(np.int32), 50)
    c = api.compress(jnp.asarray(keys), "huffman")
    return c, c.to_bytes(version=version), keys


@pytest.mark.parametrize("version", [1, 2])
def test_truncated_streams_raise(version):
    _c, blob, _keys = _sample_container(version)
    # every prefix class: inside magic, header, and payload
    for cut in (2, 10, 30, len(blob) // 2, len(blob) - 1):
        with pytest.raises(ContainerError):
            Compressed.from_bytes(blob[:cut])


def test_unknown_version_raises():
    _c, blob, _keys = _sample_container()
    bad = blob[:4] + np.uint32(9).tobytes() + blob[8:]
    with pytest.raises(ContainerError, match="version"):
        Compressed.from_bytes(bad)
    with pytest.raises(ContainerError):
        Compressed.from_bytes(b"NOPE" + blob[4:])


def test_payload_bitflip_fails_crc():
    _c, blob, _keys = _sample_container()
    flipped = bytearray(blob)
    flipped[-20] ^= 0x40                       # payload bit flip
    with pytest.raises(ContainerError, match="crc32"):
        Compressed.from_bytes(bytes(flipped))


def test_header_bitflip_raises_cleanly():
    _c, blob, _keys = _sample_container()
    flipped = bytearray(blob)
    flipped[20] ^= 0xFF                        # inside the header JSON
    with pytest.raises(ContainerError):
        Compressed.from_bytes(bytes(flipped))


def test_tampered_decode_index_raises_not_garbage():
    """A decode_index that disagrees with the container metadata is
    corruption: decoding must raise, not run the fused inverse under the
    wrong chunk geometry."""
    c, _blob, keys = _sample_container()
    for field in ("chunk_size", "n_chunks", "n_symbols"):
        for tamper in ("bump", "drop"):
            evil = Compressed.from_bytes(c.to_bytes())
            for s in evil.meta["stages"]:
                if s.get("stage") == "bit_pack":
                    if tamper == "bump":   # any disagreement is corruption
                        s["decode_index"][field] += 7
                    else:                  # a gutted index is corruption too
                        del s["decode_index"][field]
            with pytest.raises(ContainerError, match="decode_index"):
                api.decode(evil)
    # sanity: the untampered stream still decodes exactly
    np.testing.assert_array_equal(np.asarray(api.decode(c)), keys)


def test_chunked_stream_corruption_raises():
    """Framed HPDS streams: truncation and header corruption raise from
    from_bytes; a payload flip inside one chunk raises from the lazy
    LazyChunks access that first touches it."""
    data = smooth_field_3d(24)
    stream = api.CompressorStream("zfp", mode="fixed",
                                  c_fixed_elems=4 * 24 * 24, rate=16)
    blob = api.CompressorStream.to_bytes(stream.compress(data))
    with pytest.raises(ContainerError):
        api.CompressorStream.from_bytes(blob[: len(blob) - 9])
    with pytest.raises(ContainerError):
        api.CompressorStream.from_bytes(b"XXXX" + blob[4:])
    flipped = bytearray(blob)
    flipped[-30] ^= 0x10                       # last chunk's payload
    res = api.CompressorStream.from_bytes(bytes(flipped))  # bounds still ok
    assert res.chunks.materialized == 0
    with pytest.raises(ContainerError):
        res.chunks[len(res.chunks) - 1]        # lazy parse hits the flip
    res.chunks[0]                              # intact chunks still parse
    assert res.chunks.materialized == 1


def test_aggregated_file_corruption_raises(tmp_path):
    """Segment files: a flipped byte fails the segment crc on pread; a
    truncated trailer is reported as a missing directory."""
    from repro.runtime.io import AggregatedReader, AggregatedWriter

    path = tmp_path / "agg.hpdr"
    with AggregatedWriter(path, align=64) as w:
        w.add("a", b"alpha" * 100)
        w.add("b", b"beta" * 100)
    raw = bytearray(path.read_bytes())
    raw[10] ^= 0x01                            # inside segment "a"
    path.write_bytes(bytes(raw))
    with AggregatedReader(path) as r:
        with pytest.raises(ContainerError, match="crc32"):
            r.read("a")
        assert r.read("b") == b"beta" * 100    # other segments unaffected
        with pytest.raises(ContainerError, match="segment"):
            r.read("missing")
    path.write_bytes(path.read_bytes()[:-4])   # torn trailer
    with pytest.raises(ContainerError, match="directory"):
        AggregatedReader(tmp_path / "agg.hpdr")


def test_v1_stream_still_reads_and_matches_v2():
    c, blob_v2, keys = _sample_container()
    blob_v1 = c.to_bytes(version=1)
    for blob in (blob_v1, blob_v2):
        c2 = Compressed.from_bytes(blob)
        np.testing.assert_array_equal(np.asarray(api.decode(c2)), keys)
    header = json.loads(blob_v1[16 : 16 + int(np.frombuffer(blob_v1[8:16], np.uint64)[0])])
    assert "crc32" not in header               # v1 really is the old layout
