"""Data pipeline determinism/resumability + LR schedules."""

import numpy as np
import jax.numpy as jnp

from repro.data import DataConfig, SyntheticLMStream
from repro.optim import schedule


def test_stream_deterministic():
    cfg = DataConfig(vocab=512, seq_len=16, global_batch=4, seed=7)
    a, b = SyntheticLMStream(cfg), SyntheticLMStream(cfg)
    for _ in range(3):
        ba, bb = a.next_batch(), b.next_batch()
        np.testing.assert_array_equal(np.asarray(ba["tokens"]), np.asarray(bb["tokens"]))


def test_stream_resume_exact():
    cfg = DataConfig(vocab=512, seq_len=16, global_batch=4, seed=7)
    a = SyntheticLMStream(cfg)
    for _ in range(5):
        a.next_batch()
    state = a.state_dict()
    next_a = np.asarray(a.next_batch()["tokens"])
    b = SyntheticLMStream(cfg)
    b.load_state_dict(state)
    next_b = np.asarray(b.next_batch()["tokens"])
    np.testing.assert_array_equal(next_a, next_b)


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=64, seq_len=8, global_batch=2, seed=0)
    s = SyntheticLMStream(cfg)
    batch = s.next_batch()
    # labels[t] == tokens[t+1] by construction of the (S+1) window
    assert batch["tokens"].shape == batch["labels"].shape == (2, 8)


def test_cosine_schedule_shape():
    lrs = [float(schedule.cosine(s, peak_lr=1.0, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0          # warmup rises
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < 0.2                    # decays toward min_ratio
    assert abs(lrs[10] - 1.0) < 0.05


def test_wsd_schedule_shape():
    lrs = [float(schedule.wsd(s, peak_lr=1.0, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] < lrs[9]                   # warmup
    assert abs(lrs[50] - 1.0) < 1e-6         # stable plateau
    assert lrs[-1] < 0.1                     # decay tail
    # plateau really is flat
    assert np.std(lrs[15:85]) < 1e-6
