"""Stacked device-resident decode path: the mirror of PR 3's encode work.

Covers the PR-4 contracts:
  * compiled inverse pipelines — every stage-graph codec compiles a decode
    direction with NO host barrier: host stages become metadata-only
    prepares, so the whole decode chain fuses into one jitted segment;
  * bit-identity — decoded arrays agree exactly across (a) xla vs
    pallas_interpret backends, (b) serial vs engine-stacked decode,
    (c) the chunk-parallel inverse pipeline vs the legacy host-orchestrated
    Huffman decoder;
  * compatibility — streams without the decode chunk index (anything
    written before this PR, simulated by stripping the per-stage index)
    still decode through the host fallback, including v1-container bytes;
  * stacked engine path — decompress_pytree groups leaves by decode spec
    into one whole-mesh shard_map submission per bucket, with CMM hit
    counters mirroring the encode direction (multi-device subprocess);
  * transfer symmetry — decode H2D is the compressed sections plus
    metadata-scale operands, never a raw-array-sized staging transfer;
  * batched-path donation — per-shard workspace stacks are donated and the
    recycled buffers re-stored (pointer-stable where XLA implements
    donation).
"""

import copy
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import adapters, api, huffman
from repro.core.codecs import get_codec
from repro.core.codecs.huffman_codec import stream_decode_index
from repro.core.engine import ExecutionEngine
from conftest import smooth_field_3d


def _strip_decode_index(c):
    """A pre-PR-4 stream: same sections, no decode chunk index."""
    old = copy.deepcopy(c)
    for s in old.meta.get("stages", ()):
        if isinstance(s, dict):
            s.pop("decode_index", None)
    return old


CASES = (
    ("mgard", {"error_bound": 1e-2}),
    ("zfp", {"rate": 16}),
    ("huffman", {}),
    ("huffman-bytes", {}),
)


def _data_for(method, rng):
    if method == "huffman":
        return np.minimum(np.abs(rng.normal(0, 25, 17000)).astype(np.int32), 400)
    return smooth_field_3d(20)


# ---------------------------------------------------------------------------
# compiled inverse structure
# ---------------------------------------------------------------------------


def test_inverse_pipelines_fuse_to_single_segment(rng):
    """Decode has no host barrier: one fused inverse segment per codec,
    preceded only by metadata-scale host prepares."""
    expected = {
        "mgard": "invert[huffman_entropy·uniform_quantize·mgard_decorrelate]",
        "zfp": "invert[zfp_block_transform]",
        "huffman": "invert[huffman_entropy·int_keys]",
        "huffman-bytes": "invert[huffman_entropy·byte_keys]",
    }
    for method, kw in CASES:
        data = _data_for(method, rng)
        pipe = api.get_plan(api.make_spec(data, method, **kw)).pipeline
        assert pipe.invertible
        assert [s.name for s in pipe.inv_segments] == [expected[method]]
        assert all(not st.device for st in pipe.inv_preps)


def test_streams_carry_decode_chunk_index(rng):
    keys = _data_for("huffman", rng)
    c = api.compress(jnp.asarray(keys), "huffman")
    idx = stream_decode_index(c)
    assert idx is not None
    assert idx["n_chunks"] == int(c.arrays["chunk_offsets"].shape[0])
    assert idx["n_symbols"] == keys.size
    # survives a byte roundtrip in both container versions
    for version in (1, 2):
        c2 = api.Compressed.from_bytes(c.to_bytes(version=version))
        assert stream_decode_index(c2) == idx


# ---------------------------------------------------------------------------
# bit-identity: backends / legacy host decoder / old streams
# ---------------------------------------------------------------------------


def test_decode_bit_identity_across_backends(rng):
    """Acceptance (a): xla and pallas_interpret decode bit-identically."""
    for method, kw in CASES:
        data = _data_for(method, rng)
        c = api.compress(jnp.asarray(data), method, backend="xla", **kw)
        out_xla = np.asarray(api.decode(c, backend="xla"))
        out_int = np.asarray(api.decode(c, backend="pallas_interpret"))
        np.testing.assert_array_equal(out_xla, out_int, err_msg=method)


def test_decode_pipeline_matches_legacy_host_decoder(rng):
    """Acceptance (c): the chunk-parallel inverse pipeline reproduces the
    host-orchestrated decoder exactly, and old streams still decode."""
    calls = {"n": 0}
    real = huffman.decode

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    for method, kw in CASES:
        data = _data_for(method, rng)
        c = api.compress(jnp.asarray(data), method, backend="xla", **kw)
        new = np.asarray(api.decode(c))
        old_stream = _strip_decode_index(c)
        before = calls["n"]
        huffman_mod_decode = huffman.decode
        try:
            huffman.decode = counting
            legacy = np.asarray(api.decode(old_stream))
        finally:
            huffman.decode = huffman_mod_decode
        np.testing.assert_array_equal(new, legacy, err_msg=method)
        if method != "zfp":  # zfp has no entropy tail (always pipeline)
            assert calls["n"] == before + 1  # fallback actually ran


def test_old_v1_stream_roundtrip(rng):
    """Pre-index v1-container bytes decode via the host fallback."""
    keys = _data_for("huffman", rng)
    c = _strip_decode_index(api.compress(jnp.asarray(keys), "huffman"))
    c2 = api.Compressed.from_bytes(c.to_bytes(version=1))
    assert stream_decode_index(c2) is None
    np.testing.assert_array_equal(np.asarray(api.decode(c2)), keys)


def test_huffman_bytes_unusual_dtypes_fall_back(rng):
    """Element types the device bitcast cannot express stay correct via the
    host fallback (decode_state returns None)."""
    f64 = rng.normal(size=257)  # float64: 8-byte elements under 32-bit jax
    c = api.compress_leaf(f64, "huffman-bytes")
    np.testing.assert_array_equal(api.decompress_leaf(c), f64)


# ---------------------------------------------------------------------------
# serial vs stacked (acceptance b)
# ---------------------------------------------------------------------------


def test_stacked_decode_bit_identical_to_serial(rng):
    tree = {f"w{i}": rng.normal(size=(48, 64)).astype(np.float32)
            for i in range(4)}
    itree = {f"k{i}": np.minimum(
        np.abs(rng.normal(0, 5 * (i + 1), 4096)).astype(np.int32), 40 * (i + 1))
        for i in range(3)}
    eng = ExecutionEngine(backend="xla")
    for src, sel in (
        (tree, lambda k, a: ("mgard", {"error_bound": 1e-2})),
        (itree, lambda k, a: ("huffman", {})),
        (tree, lambda k, a: ("zfp", {"rate": 16})),
    ):
        comp, _ = eng.compress_pytree(src, select=sel)
        before = eng.stats()["sharded_decoded_leaves"]
        out = eng.decompress_pytree(comp, src)
        assert eng.stats()["sharded_decoded_leaves"] == before + len(src)
        for k in src:
            serial = api.decompress_leaf(comp[k])
            np.testing.assert_array_equal(np.asarray(out[k]), serial)
    eng.close()


def test_stacked_decode_falls_back_for_old_streams(rng):
    """A bucket containing one pre-index stream decodes per-leaf (host
    path) and still restores exactly."""
    itree = {f"k{i}": rng.integers(0, 100, 2048).astype(np.int32)
             for i in range(3)}
    eng = ExecutionEngine(backend="xla")
    comp, _ = eng.compress_pytree(itree, select=lambda k, a: ("huffman", {}))
    comp["k1"] = _strip_decode_index(comp["k1"])
    before = eng.stats()["sharded_decoded_leaves"]
    out = eng.decompress_pytree(comp, itree)
    assert eng.stats()["sharded_decoded_leaves"] == before  # no stacked run
    for k in itree:
        np.testing.assert_array_equal(np.asarray(out[k]), itree[k])
    eng.close()


# ---------------------------------------------------------------------------
# transfer symmetry: decode H2D = compressed bytes + metadata
# ---------------------------------------------------------------------------


def test_decode_transfers_are_stream_plus_metadata(rng):
    keys = np.minimum(np.abs(rng.normal(0, 6, 1 << 16)).astype(np.int32), 63)
    spec = api.make_spec(keys, "huffman")
    c = api.encode(spec, jnp.asarray(keys))
    api.decode_profiled(c)  # warm
    out, stage_s, transfers = api.decode_profiled(c)
    np.testing.assert_array_equal(np.asarray(out), keys)
    # H2D: the compressed sections plus metadata-scale decode operands —
    # far below the raw array the decode produces
    assert transfers.h2d < keys.nbytes / 2
    assert transfers.h2d >= c.arrays["words"].nbytes
    assert transfers.h2d <= c.nbytes() + 65536
    assert transfers.d2h == 0  # nothing comes back until the caller looks
    assert any(k.startswith("invert[") for k in stage_s)
    assert "codebook_build" in stage_s


# ---------------------------------------------------------------------------
# batched-path donation (ROADMAP item)
# ---------------------------------------------------------------------------


def test_batched_workspace_donation_recycles_stacks(rng, monkeypatch):
    """The stacked path builds one per-shard workspace stack per segment,
    donates it into every dispatch, and re-stores the recycled buffers —
    the stack is built once across repeated bucket encodes."""
    monkeypatch.setattr(adapters, "supports_donation", lambda: True)
    tree = {f"w{i}": rng.normal(size=(48, 64)).astype(np.float32)
            for i in range(4)}
    eng = ExecutionEngine(backend="xla")
    try:
        sel = lambda k, a: ("mgard", {"error_bound": 1e-2})
        comp, stats = eng.compress_pytree(tree, select=sel)
        assert stats["sharded_leaves"] == 4
        s = eng.stats()
        assert s["ws_donated_calls"] >= 1       # quantize segment donated
        assert s["ws_stack_builds"] == 1        # one stack, then recycled
        assert eng._ws_stacks                   # recycled stack re-stored
        comp2, _ = eng.compress_pytree(tree, select=sel)
        s2 = eng.stats()
        assert s2["ws_stack_builds"] == 1       # reused, not rebuilt
        assert s2["ws_donated_calls"] > s["ws_donated_calls"]
        # streams stay bit-identical to the serial (broadcast-free) encode
        for k in tree:
            serial = api.compress_leaf(
                tree[k], "mgard", error_bound=1e-2, backend="xla")
            assert comp2[k].to_bytes() == serial.to_bytes()
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# stacked multi-device subprocess (acceptance: CMM counters + one
# whole-mesh submission per decode bucket)
# ---------------------------------------------------------------------------


@pytest.mark.subprocess
def test_stacked_decode_multidevice_subprocess():
    if jax.device_count() >= 2:
        pytest.skip("in-process mesh already multi-device; covered inline")
    script = textwrap.dedent("""
        import json
        import numpy as np
        import jax
        from repro.core import api
        from repro.core.context import GLOBAL_CMM
        from repro.core.engine import ExecutionEngine

        rng = np.random.default_rng(0)
        tree = {f"w{i}": rng.normal(size=(48, 64)).astype(np.float32)
                for i in range(8)}
        itree = {f"k{i}": rng.integers(0, 200, 4096).astype(np.int32)
                 for i in range(4)}
        eng = ExecutionEngine(backend="xla")
        comp, _ = eng.compress_pytree(
            tree, select=lambda k, a: ("mgard", {"error_bound": 1e-2}))
        comp2, _ = eng.compress_pytree(
            itree, select=lambda k, a: ("huffman", {}))
        GLOBAL_CMM.clear()
        h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count
        mesh0 = eng.stats()["mesh_submitted"]
        smap0 = eng.stats()["shard_map_calls"]
        h2d0 = eng.stats()["transfer_h2d"]
        out = eng.decompress_pytree(comp, tree)
        out2 = eng.decompress_pytree(comp2, itree)
        stream_bytes = sum(c.nbytes() for c in comp.values())
        stream_bytes += sum(c.nbytes() for c in comp2.values())
        raw_bytes = (sum(a.nbytes for a in tree.values())
                     + sum(a.nbytes for a in itree.values()))
        exact = all((np.asarray(out2[k]) == itree[k]).all() for k in itree)
        serial_ok = all(
            (np.asarray(out[k]) == api.decompress_leaf(comp[k])).all()
            for k in tree
        )
        print(json.dumps({
            "devices": jax.device_count(),
            "engine_devices": len(eng.devices),
            "sharded_decoded": eng.stats()["sharded_decoded_leaves"],
            "mesh_submissions": eng.stats()["mesh_submitted"] - mesh0,
            "shard_map_calls": eng.stats()["shard_map_calls"] - smap0,
            "decode_h2d": eng.stats()["transfer_h2d"] - h2d0,
            "stream_bytes": stream_bytes,
            "raw_bytes": raw_bytes,
            "hits": GLOBAL_CMM.hit_count - h0,
            "misses": GLOBAL_CMM.miss_count - m0,
            "exact": exact,
            "serial_ok": serial_ok,
        }))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (
        str(Path(__file__).resolve().parent.parent / "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=480,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    assert report["devices"] >= 2 and report["engine_devices"] >= 2
    assert report["sharded_decoded"] == 8 + 4   # both buckets stacked
    # one whole-mesh submission per decode bucket, one fused inverse
    # segment each — not one future per leaf
    assert report["mesh_submissions"] == 2
    assert report["shard_map_calls"] == 2
    # CMM: decode plans resolved per leaf — the first leaf of each bucket
    # is the only miss, every further leaf a real hit
    assert report["misses"] == 2
    assert report["hits"] >= (8 - 1) + (4 - 1)
    # H2D symmetry: compressed sections (stack-padded per bucket) plus
    # metadata-scale operands.  If decode staged the raw arrays the count
    # would exceed raw_bytes by construction; the exact per-leaf accounting
    # is asserted in test_decode_transfers_are_stream_plus_metadata.
    assert report["decode_h2d"] < report["raw_bytes"]
    assert report["decode_h2d"] >= report["stream_bytes"] // 2
    assert report["exact"] and report["serial_ok"]


# ---------------------------------------------------------------------------
# mixed chunk geometry (ROADMAP item): group, don't merge-by-max
# ---------------------------------------------------------------------------


def test_stacked_decode_groups_mixed_chunk_sizes(rng):
    """Same-spec streams packed with different chunk_size must decode in
    separate stacked dispatches — merging their statics by max used to
    decode the smaller-chunk streams as garbage."""
    itree = {f"k{i}": np.minimum(
        np.abs(rng.normal(0, 20, 4096)).astype(np.int32), 300)
        for i in range(4)}
    sel = lambda k, a: ("huffman",
                        {"chunk_size": 512 if k in ("k1", "k3") else 4096})
    eng = ExecutionEngine(backend="xla")
    try:
        comp, _ = eng.compress_pytree(itree, select=sel)
        assert {comp[k].meta["chunk_size"] for k in itree} == {512, 4096}
        # decode specs are identical (chunk_size is encode-side only) …
        specs = {get_codec(c.method).decode_spec(c).key() for c in comp.values()}
        assert len(specs) == 1
        before = eng.stats()["sharded_decoded_leaves"]
        smap0 = eng.stats()["shard_map_calls"]
        out = eng.decompress_pytree(comp, itree)
        # … yet both geometry groups ran stacked, one dispatch each
        assert eng.stats()["sharded_decoded_leaves"] == before + 4
        assert eng.stats()["shard_map_calls"] == smap0 + 2
        for k in itree:
            np.testing.assert_array_equal(np.asarray(out[k]), itree[k])
            serial = api.decompress_leaf(comp[k])
            np.testing.assert_array_equal(np.asarray(out[k]), serial)
    finally:
        eng.close()


def test_mixed_chunk_size_merge_is_rejected_at_stage_level(rng):
    """Defence in depth: if mixed geometries ever reach one stacked batch,
    the strict chunk_size merge refuses instead of decoding garbage."""
    from repro.core.stages.library import CodebookBuild

    st = CodebookBuild()
    assert st.merge_static("n_symbols", [4096, 1024]) == 4096  # pad: safe
    with pytest.raises(ValueError, match="chunk_size"):
        st.merge_static("chunk_size", [4096, 512])
