"""Execution engine: plan-bound backends, sharded fan-out, async submission.

Covers the three engine contracts:
  * backend parity — every runnable adapter produces bit-identical
    encode/decode for every registered codec (and the six kernel ops agree
    pairwise across adapters);
  * sharded fan-out — ``compress_pytree`` buckets leaves by post-policy
    spec, builds one plan per bucket (CMM miss counters), and schedules
    buckets over the mesh "data" axis (a ≥2-device CPU mesh is exercised in
    a subprocess with ``--xla_force_host_platform_device_count``, since the
    in-process device count is fixed at backend init);
  * async submission — submit()/result() futures, the checkpoint manager's
    io-lane save, and serving-side background KV parking.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import api
from repro.core.adapters import available_backends, resolve_backend, supports_donation
from repro.core.context import GLOBAL_CMM
from repro.core.engine import ExecutionEngine, data_devices, make_data_mesh
from conftest import smooth_field_3d

ALL_METHODS = [
    ("mgard", {"error_bound": 1e-2}),
    ("zfp", {"rate": 12}),
    ("huffman", {}),
    ("huffman-bytes", {}),
]


def _data_for(method, rng):
    if method == "huffman":
        return np.minimum(np.abs(rng.normal(0, 10, 8192)).astype(np.int32), 255)
    return smooth_field_3d(24)


# ---------------------------------------------------------------------------
# backend resolution + plan binding
# ---------------------------------------------------------------------------


def test_backend_resolution():
    assert resolve_backend(None) == resolve_backend("auto")
    assert resolve_backend("auto") in available_backends()
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("cuda-graphs")
    if "pallas" not in available_backends():  # CPU container
        with pytest.raises(ValueError, match="not runnable"):
            resolve_backend("pallas")


def test_spec_backend_is_plan_bound():
    f = smooth_field_3d(16)
    spec = api.make_spec(f, "zfp", rate=9, backend="pallas_interpret")
    assert spec.backend == "pallas_interpret"  # resolved at spec build
    plan = api.get_plan(spec)
    assert plan.spec.backend == "pallas_interpret"
    # auto and the explicit platform default share one CMM entry
    auto = api.make_spec(f, "zfp", rate=9)
    explicit = api.make_spec(f, "zfp", rate=9, backend=resolve_backend("auto"))
    assert auto.key() == explicit.key()
    # ...but a different backend is a different plan
    assert spec.key() != auto.key() or resolve_backend("auto") == "pallas_interpret"


@pytest.mark.parametrize("method,kw", ALL_METHODS)
def test_backend_parity_all_codecs(method, kw, rng):
    """xla and pallas_interpret produce bit-identical streams and decodes."""
    data = _data_for(method, rng)
    streams, decoded = {}, {}
    for backend in ("xla", "pallas_interpret"):
        c = api.compress(jnp.asarray(data), method, backend=backend, **kw)
        streams[backend] = c.to_bytes()
        decoded[backend] = np.asarray(api.decode(c, backend=backend))
    assert streams["xla"] == streams["pallas_interpret"]
    np.testing.assert_array_equal(decoded["xla"], decoded["pallas_interpret"])


def test_cross_backend_decode_portability(rng):
    """A stream written under one backend decodes under any other."""
    f = smooth_field_3d(16)
    c = api.compress(jnp.asarray(f), "mgard", backend="pallas_interpret")
    c2 = api.Compressed.from_bytes(c.to_bytes())
    np.testing.assert_array_equal(
        np.asarray(api.decode(c2, backend="xla")),
        np.asarray(api.decode(c, backend="pallas_interpret")),
    )


def test_kernel_ops_adapter_parity(rng):
    """All six kernel ops agree across registered adapters (bitstream ops
    bit-identically; the float tridiag solver to accumulation tolerance)."""
    from repro.kernels.histogram import ops as hist_ops
    from repro.kernels.huffman_encode import ops as enc_ops
    from repro.kernels.mgard_lerp import ops as lerp_ops
    from repro.kernels.quantize_map import ops as quant_ops
    from repro.kernels.tridiag import ops as tri_ops
    from repro.kernels.zfp_block import ops as zfp_ops

    a, b = "xla", "pallas_interpret"
    blocks = rng.normal(size=(40, 64)).astype(np.float32)
    for enc, dec in ((a, b), (b, a)):
        p, e = zfp_ops.compress_blocks(jnp.asarray(blocks), 12, 3, adapter=enc)
        p2, e2 = zfp_ops.compress_blocks(jnp.asarray(blocks), 12, 3, adapter=dec)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(p2))
        np.testing.assert_array_equal(
            np.asarray(zfp_ops.decompress_blocks(p, e, 12, 3, adapter=dec)),
            np.asarray(zfp_ops.decompress_blocks(p2, e2, 12, 3, adapter=enc)),
        )
    keys = rng.integers(0, 500, 20000).astype(np.int32)
    np.testing.assert_array_equal(
        np.asarray(hist_ops.histogram(jnp.asarray(keys), 512, adapter=a)),
        np.asarray(hist_ops.histogram(jnp.asarray(keys), 512, adapter=b)),
    )
    codes = rng.integers(0, 2**16, 512).astype(np.uint32)
    lens = rng.integers(1, 17, 512).astype(np.int32)
    ca, la = enc_ops.encode_lookup(jnp.asarray(keys), jnp.asarray(codes),
                                   jnp.asarray(lens), adapter=a)
    cb, lb = enc_ops.encode_lookup(jnp.asarray(keys), jnp.asarray(codes),
                                   jnp.asarray(lens), adapter=b)
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    x = rng.normal(size=9000).astype(np.float32)
    lv = rng.integers(0, 5, 9000).astype(np.int32)
    bins = (10.0 ** -rng.uniform(2, 4, 5)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(quant_ops.quantize(jnp.asarray(x), jnp.asarray(lv),
                                      jnp.asarray(bins), adapter=a)),
        np.asarray(quant_ops.quantize(jnp.asarray(x), jnp.asarray(lv),
                                      jnp.asarray(bins), adapter=b)),
    )
    rows = rng.normal(size=(7, 33)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(lerp_ops.lerp_coefficients(jnp.asarray(rows), adapter=a)),
        np.asarray(lerp_ops.lerp_coefficients(jnp.asarray(rows), adapter=b)),
    )
    np.testing.assert_allclose(
        np.asarray(tri_ops.solve_mass(jnp.asarray(rows), 2.0, adapter=a)),
        np.asarray(tri_ops.solve_mass(jnp.asarray(rows), 2.0, adapter=b)),
        rtol=3e-5, atol=3e-6,
    )


# ---------------------------------------------------------------------------
# workspace donation
# ---------------------------------------------------------------------------


def test_mgard_workspace_recycled_through_donation_path():
    """The planned quantize/dequantize executables return the (donated)
    level map and the codec re-stores it — true in-place recycling where
    the platform implements donation, a pass-through elsewhere."""
    f = smooth_field_3d(16)
    spec = api.make_spec(f, "mgard", error_bound=1e-2, dict_size=1024)
    plan = api.get_plan(spec)
    lmap_before = np.asarray(plan.workspace["lmap"]).copy()
    c1 = api.encode(spec, jnp.asarray(f))
    c2 = api.encode(spec, jnp.asarray(f))
    assert c1.to_bytes() == c2.to_bytes()  # recycling never corrupts results
    assert "lmap" in plan.workspace
    np.testing.assert_array_equal(np.asarray(plan.workspace["lmap"]), lmap_before)
    out = np.asarray(api.decode(c2))
    vr = f.max() - f.min()
    assert np.abs(out - f).max() <= 2e-2 * vr
    # donation is a platform capability, not a hard requirement
    assert isinstance(supports_donation(), bool)


# ---------------------------------------------------------------------------
# engine fan-out (current device count; ≥2-device mesh below via subprocess)
# ---------------------------------------------------------------------------


def test_engine_bucketed_pytree_single_plan_per_bucket(rng):
    tree = {
        "a": rng.normal(size=(64, 128)).astype(np.float32),
        "b": rng.normal(size=(128, 64)).astype(np.float32),   # same blocked shape
        "c": rng.normal(size=(64, 128)).astype(np.float32),
        "ids": np.arange(32, dtype=np.int32),                 # raw passthrough
    }
    eng = ExecutionEngine()
    h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count
    GLOBAL_CMM.clear()
    comp, stats = eng.compress_pytree(tree, select=lambda k, a: (
        ("zfp", {"rate": 8}) if a.dtype.kind == "f" else None))
    hits = GLOBAL_CMM.hit_count - h0
    misses = GLOBAL_CMM.miss_count - m0
    assert stats["leaves"] == 4 and stats["compressed_leaves"] == 3
    assert stats["buckets"] == 1          # all three flatten to (8, 32, 32)
    assert stats["sharded_leaves"] == 3   # zfp leaves ran the shard_map path
    assert misses == 1                    # one plan build per bucket
    assert hits >= 2                      # every other leaf a real CMM hit
    out = eng.decompress_pytree(comp, tree)
    for k in tree:
        a, b = np.asarray(out[k]), np.asarray(tree[k])
        assert a.shape == b.shape and a.dtype == b.dtype
    np.testing.assert_array_equal(np.asarray(out["ids"]), tree["ids"])
    eng.close()


def test_engine_matches_serial_leaf_compression(rng):
    """Engine fan-out is bit-identical to the serial compress_leaf path."""
    tree = {f"w{i}": rng.normal(size=(48, 64)).astype(np.float32) for i in range(4)}
    eng = ExecutionEngine(backend="xla")
    comp, _ = eng.compress_pytree(tree, select=lambda k, a: ("zfp", {"rate": 10}))
    for key, arr in tree.items():
        serial = api.compress_leaf(arr, "zfp", rate=10, backend="xla")
        assert comp[key].to_bytes() == serial.to_bytes()
    eng.close()


def test_engine_select_params_may_carry_backend(rng):
    """A per-leaf ``backend`` in the select policy overrides the engine's."""
    tree = {"w": rng.normal(size=(64, 128)).astype(np.float32)}
    comp, _ = api.compress_pytree(
        tree, select=lambda k, a: ("zfp", {"rate": 8, "backend": "pallas_interpret"})
    )
    serial = api.compress_leaf(tree["w"], "zfp", rate=8, backend="pallas_interpret")
    assert comp["w"].to_bytes() == serial.to_bytes()


def test_engine_mixed_methods_futures_path(rng):
    tree = {
        "f": smooth_field_3d(24),
        "g": smooth_field_3d(24, noise=0.1, seed=1),
        "k": np.arange(8192, dtype=np.int32),
    }
    eng = ExecutionEngine()

    def select(key, arr):
        if arr.dtype.kind == "f":
            return "mgard", {"error_bound": 1e-2}
        return "huffman-bytes", {}

    comp, stats = eng.compress_pytree(tree, select=select)
    assert stats["compressed_leaves"] == 3
    assert stats["buckets"] == 2          # mgard bucket + huffman-bytes bucket
    out = eng.decompress_pytree(comp, tree)
    np.testing.assert_array_equal(np.asarray(out["k"]), tree["k"])
    for k in ("f", "g"):
        vr = tree[k].max() - tree[k].min()
        assert np.abs(np.asarray(out[k]) - tree[k]).max() <= 2e-2 * vr
    eng.close()


def test_engine_submit_result_futures(rng):
    eng = ExecutionEngine()
    f = smooth_field_3d(16)
    spec = eng.make_spec(f, "zfp", rate=8)
    subs = [eng.submit_encode(spec, f) for _ in range(4)]
    blobs = {eng.result(s).to_bytes() for s in subs}
    assert len(blobs) == 1  # all futures agree
    c = subs[0].result()
    dec = eng.submit_decode(c)
    assert np.asarray(dec.result()).shape == f.shape
    assert eng.stats()["submitted"] >= 5
    eng.close()


@pytest.mark.subprocess
def test_engine_fanout_multidevice_subprocess(tmp_path):
    """Acceptance: on a ≥2-device mesh, compress_pytree shards leaves over
    the data axis with one plan build per bucket (CMM counters).

    The in-process device count is locked at backend init, so the multi-
    device CPU mesh runs in a subprocess with
    ``--xla_force_host_platform_device_count=4``.
    """
    if jax.device_count() >= 2:
        pytest.skip("in-process mesh already multi-device; covered inline")
    script = textwrap.dedent("""
        import json
        import numpy as np
        import jax
        from repro.core import api
        from repro.core.context import GLOBAL_CMM
        from repro.core.engine import ExecutionEngine

        rng = np.random.default_rng(0)
        tree = {f"w{i}": rng.normal(size=(64, 128)).astype(np.float32)
                for i in range(8)}
        eng = ExecutionEngine()
        GLOBAL_CMM.clear()
        h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count
        comp, stats = eng.compress_pytree(
            tree, select=lambda k, a: ("zfp", {"rate": 8}))
        out = eng.decompress_pytree(comp, tree)
        exact = all(np.asarray(out[k]).shape == tree[k].shape for k in tree)
        print(json.dumps({
            "devices": jax.device_count(),
            "engine_devices": len(eng.devices),
            "buckets": stats["buckets"],
            "sharded_leaves": stats["sharded_leaves"],
            "hits": GLOBAL_CMM.hit_count - h0,
            "misses": GLOBAL_CMM.miss_count - m0,
            "shapes_ok": exact,
        }))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (
        str(Path(__file__).resolve().parent.parent / "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=480,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    assert report["devices"] >= 2
    assert report["engine_devices"] >= 2
    assert report["buckets"] == 1
    assert report["sharded_leaves"] == 8      # all leaves over the data axis
    assert report["misses"] == 1              # one plan build per bucket
    assert report["hits"] >= 7                # shards are real CMM hits
    assert report["shapes_ok"]


def test_data_devices_and_mesh_helpers():
    mesh = make_data_mesh()
    assert mesh.axis_names == ("data",)
    assert len(data_devices(mesh)) == len(jax.devices())
    from repro.launch.mesh import data_axis_size, make_data_mesh as launch_mesh

    m2 = launch_mesh()
    assert data_axis_size(m2) == len(jax.devices())


# ---------------------------------------------------------------------------
# async orchestration (checkpoint io lane, serving KV parking)
# ---------------------------------------------------------------------------


def test_checkpoint_save_async_runs_on_engine(tmp_path, rng):
    from repro.checkpoint import CheckpointManager, CheckpointPolicy

    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    tree = {"w": rng.normal(size=(64, 64)).astype(np.float32)}
    sub = mgr.save_async(11, tree)
    assert sub.lane == "io"
    manifest = mgr.wait()
    assert manifest["step"] == 11
    assert mgr.latest_step() == 11
    out, _ = mgr.restore(11, target=tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])


def test_checkpoint_colliding_leaf_keys_get_distinct_files(tmp_path, rng):
    """Keys that sanitize to the same segment name must not share one."""
    from repro.checkpoint import CheckpointManager, CheckpointPolicy

    tree = {
        "a/b": rng.normal(size=(16, 16)).astype(np.float32),
        "a_b": rng.normal(size=(16, 16)).astype(np.float32),
    }
    mgr = CheckpointManager(tmp_path, CheckpointPolicy(exact=True))
    manifest = mgr.save(1, tree)
    files = [info["segment"] for info in manifest["leaves"].values()]
    assert len(files) == len(set(files))
    out, _ = mgr.restore(1, target=tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), tree[k])


def test_park_kv_cache_async(rng):
    from repro.serving.engine import decompress_kv_cache, park_kv_cache_async

    cache = {
        "k": rng.normal(size=(2, 4, 64, 8, 16)).astype(np.float32),
        "v": rng.normal(size=(2, 4, 64, 8, 16)).astype(np.float32),
        "pos": np.arange(4, dtype=np.int32),
    }
    sub = park_kv_cache_async(cache, rate=16)
    comp, stats = sub.result()
    assert stats["compressed_leaves"] == 2
    restored = decompress_kv_cache(comp, cache)
    np.testing.assert_array_equal(np.asarray(restored["pos"]), cache["pos"])
    for k in ("k", "v"):
        err = np.abs(np.asarray(restored[k]) - cache[k]).max()
        assert err < 1e-2 * np.abs(cache[k]).max()


# ---------------------------------------------------------------------------
# lazy chunked-stream fetch
# ---------------------------------------------------------------------------


def test_stream_from_bytes_is_lazy():
    data = smooth_field_3d(32)
    stream = api.CompressorStream("zfp", mode="fixed",
                                  c_fixed_elems=8 * 32 * 32, rate=16)
    res = stream.compress(data)
    assert len(res.chunks) > 2
    blob = api.CompressorStream.to_bytes(res)

    res2 = api.CompressorStream.from_bytes(blob)
    assert isinstance(res2.chunks, api.LazyChunks)
    assert res2.chunks.materialized == 0     # nothing parsed yet
    first = res2.chunks[0]                   # progressive prefix fetch
    assert res2.chunks.materialized == 1
    np.testing.assert_array_equal(
        np.asarray(api.decompress(first)), np.asarray(api.decompress(res.chunks[0]))
    )
    # full decompress touches (and caches) every chunk exactly once
    out = stream.decompress(res2)
    assert res2.chunks.materialized == len(res2.chunks)
    np.testing.assert_array_equal(out, stream.decompress(res))
    # eager mode still available
    res3 = api.CompressorStream.from_bytes(blob, lazy=False)
    assert isinstance(res3.chunks, list)


def test_stream_lazy_bounds_validated_eagerly():
    data = smooth_field_3d(32)
    stream = api.CompressorStream("zfp", mode="fixed",
                                  c_fixed_elems=8 * 32 * 32, rate=16)
    blob = api.CompressorStream.to_bytes(stream.compress(data))
    with pytest.raises(ValueError, match="truncated"):
        api.CompressorStream.from_bytes(blob[: len(blob) - 7])


# ---------------------------------------------------------------------------
# executor lifecycle: idempotent shutdown, drain, lane metrics, chaining
# ---------------------------------------------------------------------------


def test_executor_shutdown_idempotent_and_submit_after_close():
    from repro.runtime.executor import DeviceExecutor

    ex = DeviceExecutor(jax.devices())
    assert ex.submit(lambda: 41 + 1).result() == 42
    ex.shutdown()
    assert ex.closed
    ex.shutdown()  # second shutdown: no-op, no hang, no error
    ex.shutdown(wait=False)
    with pytest.raises(RuntimeError, match="shut down"):
        ex.submit(lambda: 0)
    with pytest.raises(RuntimeError, match="shut down"):
        ex.submit(lambda: 0, lane="io")


def test_executor_shutdown_safe_under_concurrent_submit():
    import threading

    from repro.runtime.executor import DeviceExecutor

    ex = DeviceExecutor(jax.devices())
    stop = threading.Event()
    outcomes = {"ok": 0, "refused": 0, "other": []}

    def spammer():
        while not stop.is_set():
            try:
                ex.submit(lambda: 1).result()
                outcomes["ok"] += 1
            except RuntimeError as e:
                if "shut down" in str(e):
                    outcomes["refused"] += 1
                    return
                outcomes["other"].append(e)  # pragma: no cover
                return

    threads = [threading.Thread(target=spammer) for _ in range(4)]
    for t in threads:
        t.start()
    import time as _time

    _time.sleep(0.05)
    ex.shutdown()  # races against in-flight submits
    stop.set()
    for t in threads:
        t.join(30)
    assert not any(t.is_alive() for t in threads)
    # every spammer either succeeded or got the clear refusal — nothing hung
    assert not outcomes["other"]
    st = ex.lane_stats()
    total = sum(v["submitted"] for v in st.values())
    assert total == sum(v["completed"] for v in st.values())


def test_executor_drain_and_lane_stats():
    import threading
    import time as _time

    from repro.runtime.executor import DeviceExecutor

    ex = DeviceExecutor(jax.devices())
    gate = threading.Event()
    subs = [ex.submit(gate.wait, 30) for _ in range(3)]
    subs.append(ex.submit(gate.wait, 30, lane="io"))
    assert not ex.drain(timeout=0.1)  # gated work: drain times out False
    st = ex.lane_stats()
    assert st["compute"]["submitted"] == 3 and st["io"]["submitted"] == 1
    assert st["compute"]["depth"] + st["compute"]["inflight"] > 0
    gate.set()
    assert ex.drain(timeout=30)  # all lanes idle
    for s in subs:
        s.result()
    st = ex.lane_stats()
    for lane in ("compute", "io"):
        assert st[lane]["completed"] == st[lane]["submitted"]
        assert st[lane]["depth"] == 0 and st[lane]["inflight"] == 0
        assert st[lane]["wait_s"] >= 0.0
    t0 = _time.monotonic()
    assert ex.drain(timeout=5)  # idle drain returns immediately
    assert _time.monotonic() - t0 < 1.0
    ex.shutdown()


def test_executor_submit_after_propagates_upstream_failure():
    from repro.runtime.executor import DeviceExecutor

    ex = DeviceExecutor(jax.devices())

    def boom():
        raise ValueError("upstream boom")

    first = ex.submit(boom)
    chained = ex.submit_after(first, lambda r: r + 1)
    with pytest.raises(ValueError, match="upstream boom"):
        chained.result(timeout=30)
    # a healthy chain on the same executor still works afterwards
    ok = ex.submit_after(ex.submit(lambda: 2), lambda r: r + 3)
    assert ok.result(timeout=30) == 5
    ex.shutdown()


def test_executor_done_callback_fires_with_submission():
    import threading

    from repro.runtime.executor import DeviceExecutor

    ex = DeviceExecutor(jax.devices())
    seen = []
    done = threading.Event()
    sub = ex.submit(lambda: "payload")

    def cb(s):
        seen.append(s.result())
        done.set()

    sub.add_done_callback(cb)
    assert done.wait(30)
    assert seen == ["payload"]
    ex.shutdown()


def test_executor_drain_waits_for_completion_callbacks():
    """Regression: ``drain()`` returned once a *task* finished, before its
    done-callbacks ran — a callback chaining io-lane work (the serving /
    checkpoint pattern) could still be submitting after a "successful"
    drain, and shutdown would strand it.  Drain must not return between a
    submission completing and its completion callbacks finishing."""
    import threading
    import time as _time

    from repro.runtime.executor import DeviceExecutor

    ex = DeviceExecutor(jax.devices())
    rounds = 25
    for _ in range(rounds):
        gate = threading.Event()
        hits = []
        first = ex.submit(gate.wait, 30)
        # continuation rides the io lane, submitted from first's callback
        chained = ex.submit_after(
            first, lambda _r: (_time.sleep(0.002), hits.append("io"))[-1],
            lane="io",
        )
        gate.set()
        assert ex.drain(timeout=30)
        # a successful drain means the chained io work already RAN
        assert hits == ["io"]
        assert chained.done()
    st = ex.lane_stats()
    assert st["io"]["submitted"] == rounds
    assert st["io"]["completed"] == rounds
    assert st["compute"]["completed"] == st["compute"]["submitted"]
    # plain done-callbacks too: drain covers them, not just chains
    flags = []
    sub = ex.submit(lambda: 41 + 1)
    sub.add_done_callback(lambda s: (_time.sleep(0.01), flags.append(s.result())))
    assert ex.drain(timeout=30)
    assert flags == [42]
    ex.shutdown()


def test_executor_priority_stats_tagged_lanes():
    """`submit(..., priority=)` feeds per-class counters independent of the
    physical lane — the serving layer's interactive/bulk split."""
    import threading

    from repro.runtime.executor import DeviceExecutor

    ex = DeviceExecutor(jax.devices())
    gate = threading.Event()
    subs = [ex.submit(gate.wait, 30, priority="bulk") for _ in range(3)]
    subs.append(ex.submit(gate.wait, 30, lane="io", priority="interactive"))
    ex.submit(lambda: 0).result()  # untagged: must not appear below
    st = ex.priority_stats()
    assert st["bulk"]["submitted"] == 3
    assert st["interactive"]["submitted"] == 1
    assert set(st) == {"bulk", "interactive"}
    gate.set()
    assert ex.drain(timeout=30)
    st = ex.priority_stats()
    for cls in ("bulk", "interactive"):
        assert st[cls]["completed"] == st[cls]["submitted"]
        assert st[cls]["depth"] == 0 and st[cls]["inflight"] == 0
        assert st[cls]["wait_s"] >= 0.0
    ex.shutdown()
