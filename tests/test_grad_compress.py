"""Gradient compression: quantization bounds, error feedback, convergence."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.optim import grad_compress as gc


def test_quantize_roundtrip_bound(rng):
    g = rng.normal(size=10000).astype(np.float32)
    q, s = gc.quantize_blocks(jnp.asarray(g), bits=8)
    out = np.asarray(gc.dequantize_blocks(q, s, g.shape))
    # per-block error <= scale/2 = absmax/127/2
    blocks = np.pad(g, (0, (-len(g)) % gc.BLOCK)).reshape(-1, gc.BLOCK)
    bound = np.abs(blocks).max(1) / 127.0 / 2.0 + 1e-8
    err = np.abs(out - g)
    err_blocks = np.pad(err, (0, (-len(err)) % gc.BLOCK)).reshape(-1, gc.BLOCK)
    assert (err_blocks.max(1) <= bound + 1e-7).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(10, 5000), st.integers(0, 2**31))
def test_ef_residual_property(n, seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=n).astype(np.float32)
    res = jnp.zeros(n)
    (q, s), new_res = gc.ef_step(jnp.asarray(g), res, bits=8)
    approx = np.asarray(gc.dequantize_blocks(q, s, g.shape))
    # residual == exactly what compression lost
    np.testing.assert_allclose(np.asarray(new_res), g - approx, rtol=1e-5, atol=1e-6)


def test_error_feedback_accumulates_to_truth(rng):
    """Σ transmitted ≈ Σ true gradients (EF keeps long-run sums unbiased)."""
    n, steps = 512, 50
    res = jnp.zeros(n)
    total_true = np.zeros(n)
    total_sent = np.zeros(n)
    for i in range(steps):
        g = rng.normal(size=n).astype(np.float32) * 0.1
        total_true += g
        (q, s), res = gc.ef_step(jnp.asarray(g), res, bits=4)  # aggressive 4-bit
        total_sent += np.asarray(gc.dequantize_blocks(q, s, g.shape))
    # all that's missing is the final residual
    np.testing.assert_allclose(total_sent + np.asarray(res), total_true,
                               rtol=1e-4, atol=1e-4)


def test_ef_sgd_converges(rng):
    """EF-compressed SGD reaches the same optimum on a quadratic."""
    dim = 64
    target = rng.normal(size=dim).astype(np.float32)
    for bits, tol in ((8, 1e-3), (4, 5e-3)):
        x = np.zeros(dim, np.float32)
        res = jnp.zeros(dim)
        for _ in range(300):
            g = x - target
            (q, s), res = gc.ef_step(jnp.asarray(g), res, bits=bits)
            x = x - 0.2 * np.asarray(gc.dequantize_blocks(q, s, g.shape))
        assert np.abs(x - target).max() < tol * np.abs(target).max() + tol


def test_pod_compressed_mean_shardmap():
    """pod_compressed_mean inside shard_map equals the true mean (±quant err)."""
    import jax.experimental.shard_map as shard_map

    n_dev = len(jax.devices())
    if n_dev < 2:
        import pytest

        pytest.skip("needs >=2 devices")
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2,), ("pod",))
    from jax.sharding import PartitionSpec as P

    g = jnp.arange(2 * 512, dtype=jnp.float32).reshape(2, 512) / 100.0

    def f(local):
        return gc.pod_compressed_mean(local[0], axis_name="pod")

    out = shard_map.shard_map(
        f, mesh=mesh, in_specs=P("pod", None), out_specs=P(None)
    )(g)
    true = np.asarray(g).mean(0)
    np.testing.assert_allclose(np.asarray(out), true, atol=np.abs(true).max() / 100)
