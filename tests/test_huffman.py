"""Huffman-X: codebook validity, lossless roundtrip, length limiting."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import huffman as hf


def test_roundtrip_skewed(rng):
    keys = np.minimum(np.abs(rng.normal(0, 30, 50000)).astype(np.int32), 1023)
    enc = hf.compress(jnp.asarray(keys), 1024)
    out = np.asarray(hf.decompress(enc))
    assert (out == keys).all()
    assert enc.nbytes() < keys.nbytes  # actually compresses skewed data


def test_single_symbol():
    keys = np.zeros(777, np.int32)
    enc = hf.compress(jnp.asarray(keys), 8)
    assert (np.asarray(hf.decompress(enc)) == keys).all()


def test_two_symbols(rng):
    keys = rng.integers(0, 2, 4096).astype(np.int32)
    enc = hf.compress(jnp.asarray(keys), 2)
    assert (np.asarray(hf.decompress(enc)) == keys).all()
    assert enc.total_bits == 4096  # 1 bit/symbol exactly


def test_kraft_and_prefix_free(rng):
    freq = rng.integers(0, 1000, 257)
    book = hf.build_codebook(freq)
    used = book.lengths > 0
    kraft = np.sum(np.exp2(-book.lengths[used].astype(np.float64)))
    assert kraft <= 1.0 + 1e-12
    # prefix-freeness: no code is a prefix of another
    codes = [
        (format(int(book.codes[s]), f"0{book.lengths[s]}b"))
        for s in np.nonzero(used)[0]
    ]
    codes.sort()
    for a, b in zip(codes, codes[1:]):
        assert not b.startswith(a), (a, b)


def test_length_limiting_fibonacci():
    freq = np.array([int(1.6**i) + 1 for i in range(64)], np.int64)
    book = hf.build_codebook(freq, max_len=12)
    assert book.max_len <= 12
    used = book.lengths > 0
    assert np.sum(np.exp2(-book.lengths[used].astype(np.float64))) <= 1.0 + 1e-12


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(100, 3000), st.integers(0, 2**31))
def test_roundtrip_property(nkeys, n, seed):
    rng = np.random.default_rng(seed)
    keys = (rng.zipf(1.5, n) % nkeys).astype(np.int32)
    enc = hf.compress(jnp.asarray(keys), nkeys)
    assert (np.asarray(hf.decompress(enc)) == keys).all()


def test_chunked_decode_boundaries(rng):
    keys = rng.integers(0, 64, 10000).astype(np.int32)
    enc = hf.compress(jnp.asarray(keys), 64, chunk_size=256)
    assert enc.chunk_offsets.shape[0] == int(np.ceil(10000 / 256))
    assert (np.asarray(hf.decompress(enc)) == keys).all()
