"""Per-kernel validation: pallas_interpret vs pure-jnp oracle, shape/dtype sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.histogram import ops as hist_ops
from repro.kernels.huffman_encode import ops as enc_ops
from repro.kernels.mgard_lerp import ops as lerp_ops
from repro.kernels.quantize_map import ops as quant_ops
from repro.kernels.tridiag import ops as tri_ops
from repro.kernels.zfp_block import ops as zfp_ops

ADAPTERS = ("pallas_interpret", "xla")


@pytest.mark.parametrize("dims", [1, 2, 3])
@pytest.mark.parametrize("rate", [8, 16, 32])
def test_zfp_block_kernel(dims, rate, rng):
    bs = 4**dims
    blocks = (rng.normal(size=(130, bs)) * 10.0 ** rng.integers(-3, 4, (130, 1))).astype(
        np.float32
    )
    p_k, e_k = zfp_ops.compress_blocks(jnp.asarray(blocks), rate, dims, adapter="pallas_interpret")
    p_r, e_r = zfp_ops.compress_blocks(jnp.asarray(blocks), rate, dims, adapter="xla")
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_array_equal(np.asarray(e_k), np.asarray(e_r))
    out_k = zfp_ops.decompress_blocks(p_k, e_k, rate, dims, adapter="pallas_interpret")
    out_r = zfp_ops.decompress_blocks(p_r, e_r, rate, dims, adapter="xla")
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


@pytest.mark.parametrize("num_bins", [16, 1000, 4096])
def test_histogram_kernel(num_bins, rng):
    keys = rng.integers(0, num_bins, 30000).astype(np.int32)
    h_k = np.asarray(hist_ops.histogram(jnp.asarray(keys), num_bins, adapter="pallas_interpret"))
    h_r = np.asarray(hist_ops.histogram(jnp.asarray(keys), num_bins, adapter="xla"))
    np.testing.assert_array_equal(h_k, h_r)
    assert h_k.sum() == keys.size


def test_huffman_encode_kernel(rng):
    k = 2048
    codes_t = rng.integers(0, 2**20, k).astype(np.uint32)
    lens_t = rng.integers(1, 21, k).astype(np.int32)
    keys = rng.integers(0, k, 50000).astype(np.int32)
    c_k, l_k = enc_ops.encode_lookup(
        jnp.asarray(keys), jnp.asarray(codes_t), jnp.asarray(lens_t),
        adapter="pallas_interpret",
    )
    c_r, l_r = enc_ops.encode_lookup(
        jnp.asarray(keys), jnp.asarray(codes_t), jnp.asarray(lens_t), adapter="xla"
    )
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
    np.testing.assert_array_equal(np.asarray(l_k), np.asarray(l_r))


@pytest.mark.parametrize("n", [1000, 65536, 100001])
def test_quantize_kernel(n, rng):
    x = rng.normal(size=n).astype(np.float32)
    lv = rng.integers(0, 6, n).astype(np.int32)
    bins = (10.0 ** -rng.uniform(2, 4, 6)).astype(np.float32)
    q_k = quant_ops.quantize(jnp.asarray(x), jnp.asarray(lv), jnp.asarray(bins), adapter="pallas_interpret")
    q_r = quant_ops.quantize(jnp.asarray(x), jnp.asarray(lv), jnp.asarray(bins), adapter="xla")
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    x_k = np.asarray(quant_ops.dequantize(q_k, jnp.asarray(lv), jnp.asarray(bins), adapter="pallas_interpret"))
    err = np.abs(x_k - x)
    assert (err <= bins[lv] / 2 + 1e-7).all()


@pytest.mark.parametrize("n", [17, 65, 4097])
def test_mgard_lerp_kernel(n, rng):
    rows = rng.normal(size=(19, n)).astype(np.float32)
    m_k = np.asarray(lerp_ops.lerp_coefficients(jnp.asarray(rows), adapter="pallas_interpret"))
    m_r = np.asarray(lerp_ops.lerp_coefficients(jnp.asarray(rows), adapter="xla"))
    np.testing.assert_array_equal(m_k, m_r)


@pytest.mark.parametrize("n,h", [(17, 1.0), (33, 2.0), (129, 8.0)])
def test_tridiag_kernel(n, h, rng):
    rhs = rng.normal(size=(23, n)).astype(np.float32)
    x_k = np.asarray(tri_ops.solve_mass(jnp.asarray(rhs), h, adapter="pallas_interpret"))
    x_r = np.asarray(tri_ops.solve_mass(jnp.asarray(rhs), h, adapter="xla"))
    np.testing.assert_allclose(x_k, x_r, rtol=3e-5, atol=3e-6)
    # verify against dense solve for the first system
    m = np.zeros((n, n))
    for i in range(n):
        m[i, i] = 2 * h / 3 if 0 < i < n - 1 else h / 3
        if i > 0:
            m[i, i - 1] = h / 6
        if i < n - 1:
            m[i, i + 1] = h / 6
    xd = np.linalg.solve(m, rhs[0])
    np.testing.assert_allclose(x_k[0], xd, rtol=2e-3, atol=2e-4)
