"""MGARD-X: decomposition losslessness, error-bound guarantee, level map."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mgard
from conftest import smooth_field_3d


@pytest.mark.parametrize(
    "shape", [(17,), (33,), (100,), (65, 65), (20, 33), (17, 9, 13), (5, 5, 5, 5)]
)
def test_decompose_recompose_lossless(shape, rng):
    u = rng.normal(size=shape).astype(np.float32)
    c = mgard.decompose(jnp.asarray(u), shape)
    r = np.asarray(mgard.recompose(c, shape))
    assert np.abs(r - u).max() < 5e-6


def test_error_bound_smooth():
    f = smooth_field_3d(48)
    vr = float(f.max() - f.min())
    for rel_eb in (1e-2, 1e-3):
        eb = rel_eb * vr
        z = mgard.compress(jnp.asarray(f), eb)
        out = np.asarray(mgard.decompress(z))
        assert np.abs(out - f).max() <= eb


def test_error_bound_noisy():
    f = smooth_field_3d(32, noise=0.1)
    eb = 1e-2 * float(f.max() - f.min())
    z = mgard.compress(jnp.asarray(f), eb, dict_size=65536)
    out = np.asarray(mgard.decompress(z))
    assert np.abs(out - f).max() <= eb


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(0, 2**31))
def test_error_bound_property(dims, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(int(x) for x in rng.integers(5, 25, dims))
    u = rng.normal(size=shape).astype(np.float32)
    eb = 1e-2 * float(u.max() - u.min())
    z = mgard.compress(jnp.asarray(u), eb, dict_size=65536)
    out = np.asarray(mgard.decompress(z))
    assert np.abs(out - u).max() <= eb, shape


def test_compression_beats_raw_on_smooth():
    f = smooth_field_3d(48)
    eb = 1e-2 * float(f.max() - f.min())
    z = mgard.compress(jnp.asarray(f), eb)
    assert mgard.compression_ratio(z) > 3.0


def test_level_map_structure():
    lm = mgard.level_map((9, 9))
    # corners of the coarsest grid are nodal (id = L)
    L = mgard.total_levels((9, 9))
    assert lm[0, 0] == L and lm[8, 8] == L and lm[0, 8] == L
    # odd nodes are finest level 0
    assert lm[1, 3] == 0 and lm[5, 5] == 0
    # stride-2-only nodes are level 1
    assert lm[2, 2] == 1
    assert lm.shape == (9, 9)


def test_outliers_roundtrip(rng):
    # data with one huge spike → outlier path must restore it within eb
    f = smooth_field_3d(16)
    f[3, 3, 3] = 100.0
    eb = 1e-3 * float(f.max() - f.min())
    z = mgard.compress(jnp.asarray(f), eb, dict_size=256)
    assert z.outlier_idx.size > 0
    out = np.asarray(mgard.decompress(z))
    assert np.abs(out - f).max() <= eb
