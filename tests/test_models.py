"""Per-architecture smoke tests: reduced config, forward + train step + decode."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config
from repro.models import build_model
from repro.models import encdec as ed
from repro.models.layers import apply_mrope, apply_rope

pytestmark = pytest.mark.slow  # model forward passes; excluded from check.sh fast

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, rng):
    batch = {}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    elif cfg.family == "vlm":
        batch["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
        pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3)).copy()
        batch["positions_3d"] = jnp.asarray(pos, jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_grad(arch, rng):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg, rng)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True)
    )(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize(
    "arch",
    ["qwen2.5-3b", "deepseek-v3-671b", "mamba2-370m", "recurrentgemma-9b",
     "seamless-m4t-medium", "qwen2-vl-72b"],
)
def test_smoke_decode(arch, rng):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(B, 16, jnp.float32)
    if cfg.family == "encdec":
        mem = ed.encode(
            params, jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.float32), cfg
        )
        cache["cross_k"], cache["cross_v"] = ed.precompute_cross(params, mem, cfg)
    step = jax.jit(model.decode_step)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B,)), jnp.int32)
    for i in range(3):
        logits, cache = step(params, tok, cache, jnp.int32(i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all()), arch
    assert logits.shape == (B, cfg.vocab)


def test_mrope_degenerates_to_rope(rng):
    x = jnp.asarray(rng.normal(size=(B, S, 4, 16)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 100, (B, S)), jnp.int32)
    pos3 = jnp.broadcast_to(pos[..., None], (B, S, 3))
    a = apply_mrope(x, pos3, 10000.0, (2, 3, 3))
    b = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_rope_preserves_norm(rng):
    x = jnp.asarray(rng.normal(size=(B, S, 4, 16)), jnp.float32)
    pos = jnp.asarray(rng.integers(0, 1000, (B, S)), jnp.int32)
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_long_context_flags():
    assert get_config("mamba2-370m").supports_long_context
    assert get_config("recurrentgemma-9b").supports_long_context
    for arch in ARCHS:
        cfg = get_config(arch)
        shapes = applicable_shapes(cfg)
        if cfg.supports_long_context:
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes


def test_ssm_chunked_matches_sequential(rng):
    """SSD chunked algorithm == direct sequential recurrence."""
    from repro.models.ssm import ssd_chunked

    b, l, h, p, g, n = 2, 24, 4, 8, 2, 16
    x = rng.normal(size=(b, l, h, p)).astype(np.float32)
    dt = np.abs(rng.normal(size=(b, l, h))).astype(np.float32) * 0.1
    A = -np.abs(rng.normal(size=(h,))).astype(np.float32)
    Bm = rng.normal(size=(b, l, g, n)).astype(np.float32)
    Cm = rng.normal(size=(b, l, g, n)).astype(np.float32)

    y_chunk = np.asarray(
        ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                    jnp.asarray(Bm), jnp.asarray(Cm), chunk=8)
    )
    # sequential oracle
    rep = h // g
    Bh = np.repeat(Bm, rep, axis=2)
    Ch = np.repeat(Cm, rep, axis=2)
    y_seq = np.zeros_like(x)
    state = np.zeros((b, h, p, n), np.float64)
    for t in range(l):
        decay = np.exp(dt[:, t] * A)  # (b,h)
        state = state * decay[..., None, None] + np.einsum(
            "bhp,bhn,bh->bhpn", x[:, t], Bh[:, t], dt[:, t]
        )
        y_seq[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    np.testing.assert_allclose(y_chunk, y_seq, rtol=2e-3, atol=2e-4)


def test_rglru_scan_matches_sequential(rng):
    from repro.models.rglru import _gates, rglru_scan
    from repro.configs import get_config
    from repro.models.rglru import init_rglru_block

    cfg = get_config("recurrentgemma-9b").smoke()
    p = init_rglru_block(KEY, cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, 64)), jnp.float32)
    out = np.asarray(rglru_scan(x, p))
    a, contrib = _gates(x, p)
    a, contrib = np.asarray(a), np.asarray(contrib)
    h = np.zeros((2, 64))
    seq = np.zeros_like(out)
    for t in range(16):
        h = a[:, t] * h + contrib[:, t]
        seq[:, t] = h
    np.testing.assert_allclose(out, seq, rtol=1e-4, atol=1e-5)
