"""MoE layer: gating invariants, grouped-vs-naive equivalence, capacity."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import moe as moe_mod

pytestmark = pytest.mark.slow  # model forward passes; excluded from check.sh fast

KEY = jax.random.PRNGKey(0)


def _cfg(group=0, experts=8, top_k=2):
    cfg = get_config("deepseek-v3-671b").smoke()
    return dataclasses.replace(
        cfg,
        moe=dataclasses.replace(cfg.moe, n_experts=experts, top_k=top_k),
        moe_group_size=group,
    )


def test_gates_normalised(rng):
    logits = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    probs, gates, idx = moe_mod._top_k_gating(logits, 2)
    np.testing.assert_allclose(np.asarray(gates).sum(-1), 1.0, rtol=1e-5)
    assert (np.asarray(idx) < 8).all()


def test_grouped_matches_naive_when_no_drops(rng):
    """With capacity_factor high enough that nothing drops, the grouped
    dispatch must equal the naive whole-batch dispatch exactly."""
    cfg_naive = _cfg(group=0)
    cfg_grouped = _cfg(group=16)
    p = moe_mod.init_moe(KEY, cfg_naive)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg_naive.d_model)), jnp.float32)
    y1, aux1 = moe_mod.moe_layer(x, p, cfg_naive, capacity_factor=8.0)
    y2, aux2 = moe_mod.moe_layer(x, p, cfg_grouped, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-4)


def test_capacity_drops_tokens(rng):
    cfg = _cfg(group=0)
    p = moe_mod.init_moe(KEY, cfg)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y_full, _ = moe_mod.moe_layer(x, p, cfg, capacity_factor=8.0)
    y_tight, _ = moe_mod.moe_layer(x, p, cfg, capacity_factor=0.1)
    # tight capacity must change (drop) some outputs
    assert np.abs(np.asarray(y_full) - np.asarray(y_tight)).max() > 1e-6


def test_a2a_fallback_on_cpu(rng):
    """Without a matching mesh, moe_impl='a2a' must fall back gracefully."""
    cfg = dataclasses.replace(_cfg(group=16), moe_impl="a2a")
    p = moe_mod.init_moe(KEY, cfg)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)), jnp.float32)
    y, aux = moe_mod.moe_layer(x, p, cfg)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()


def test_aux_loss_positive_and_balanced_lower(rng):
    cfg = _cfg(group=0)
    p = moe_mod.init_moe(KEY, cfg)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)), jnp.float32)
    _, aux = moe_mod.moe_layer(x, p, cfg)
    assert float(aux) > 0
