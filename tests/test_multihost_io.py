"""Multi-host aggregated I/O: shard writers, global manifest, topology-aware
restore, durability, and partial-shard failure handling.

Hosts are simulated two ways, mirroring the production setting at the two
granularities the layer supports:

  * threads + explicit :class:`HostTopology` objects — fast in-process
    coverage of the coordinator rendezvous, stitching, and locality paths
    (the shared-filesystem barrier only needs concurrent callers);
  * real subprocesses with ``HPDR_HOST_ID`` / ``HPDR_HOST_COUNT`` set
    (``@subprocess`` tier) — the full multi-controller contract including
    environment-driven topology detection.
"""

import json
import os
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, CheckpointPolicy
from repro.core.container import ContainerError
from repro.core.engine import ExecutionEngine
from repro.launch.mesh import (
    HostTopology,
    barrier_payloads,
    detect_topology,
    fs_barrier,
)
from repro.runtime.io import (
    AggregatedReader,
    AggregatedWriter,
    ShardSetReader,
    shard_file_name,
    stitch_shard_directories,
)


def _tree(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "layers": {
            f"w{i}": rng.normal(size=(32, 16 + i)).astype(np.float32)
            for i in range(6)
        },
        "bias": rng.normal(size=(64,)).astype(np.float32),
        "step": np.int32(11),
    }


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------


def test_detect_topology_env_override(monkeypatch):
    monkeypatch.setenv("HPDR_HOST_COUNT", "4")
    monkeypatch.setenv("HPDR_HOST_ID", "2")
    topo = detect_topology()
    assert (topo.host_id, topo.n_hosts) == (2, 4)
    assert topo.multi_host


def test_detect_topology_defaults_single_host(monkeypatch):
    monkeypatch.delenv("HPDR_HOST_COUNT", raising=False)
    monkeypatch.delenv("HPDR_HOST_ID", raising=False)
    topo = detect_topology()
    assert topo.n_hosts >= 1 and 0 <= topo.host_id < topo.n_hosts


def test_host_topology_validates_range():
    with pytest.raises(ValueError):
        HostTopology(3, 2)


def test_leaf_ownership_deterministic_partition():
    keys = [f"layer{i}::w" for i in range(40)]
    topos = [HostTopology(h, 4) for h in range(4)]
    owned = [{k for k in keys if t.owns(k)} for t in topos]
    # a partition: disjoint, covering, and stable across instances
    assert set().union(*owned) == set(keys)
    assert sum(len(o) for o in owned) == len(keys)
    again = [{k for k in keys if HostTopology(h, 4).owns(k)} for h in range(4)]
    assert owned == again


def test_fs_barrier_rendezvous_and_payloads(tmp_path):
    n = 3
    errs = []

    def host(h):
        try:
            fs_barrier(tmp_path, "sync", HostTopology(h, n), timeout=10.0,
                       payload=f"host{h}")
        except Exception as e:  # pragma: no cover - diagnostic
            errs.append(e)

    threads = [threading.Thread(target=host, args=(h,)) for h in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    payloads = barrier_payloads(tmp_path, "sync", HostTopology(0, n))
    assert payloads == {0: "host0", 1: "host1", 2: "host2"}


def test_fs_barrier_times_out_on_missing_host(tmp_path):
    with pytest.raises(TimeoutError, match="1/2"):
        fs_barrier(tmp_path, "late", HostTopology(0, 2), timeout=0.05)


# ---------------------------------------------------------------------------
# writer durability
# ---------------------------------------------------------------------------


def test_atomic_writer_commits_only_on_close(tmp_path):
    path = tmp_path / "x.hpdr"
    w = AggregatedWriter(path, atomic=True)
    w.add("a", b"payload")
    assert not path.exists()  # nothing at the target until commit
    w.close()
    assert path.exists()
    assert not list(tmp_path.glob("*.tmp*"))  # staging file renamed away
    with AggregatedReader(path) as r:
        assert r.read("a") == b"payload"


def test_atomic_writer_abort_leaves_no_trace(tmp_path):
    path = tmp_path / "x.hpdr"
    with pytest.raises(RuntimeError):
        with AggregatedWriter(path, atomic=True) as w:
            w.add("a", b"payload")
            raise RuntimeError("crash mid-save")
    assert not path.exists()
    assert not list(tmp_path.glob("*"))  # temp staging file unlinked too


def test_atomic_writer_overwrite_keeps_old_until_commit(tmp_path):
    path = tmp_path / "x.hpdr"
    with AggregatedWriter(path, atomic=True) as w:
        w.add("a", b"old-bytes")
    with pytest.raises(RuntimeError):
        with AggregatedWriter(path, atomic=True) as w:
            w.add("a", b"new-bytes")
            raise RuntimeError("crash before commit")
    with AggregatedReader(path) as r:  # the old file survived the torn write
        assert r.read("a") == b"old-bytes"


def test_fsync_atomic_writer_roundtrip(tmp_path):
    path = tmp_path / "x.hpdr"
    with AggregatedWriter(path, fsync=True, atomic=True) as w:
        w.add("a", b"durable")
    with AggregatedReader(path) as r:
        assert r.read("a") == b"durable"


# ---------------------------------------------------------------------------
# stitching + shard-set reads (io layer)
# ---------------------------------------------------------------------------


def _write_shards(directory: Path, n_hosts: int, blobs_per_host: int = 3):
    names = {}
    for h in range(n_hosts):
        with AggregatedWriter(
            directory / shard_file_name(h), meta={"host": h}
        ) as w:
            for i in range(blobs_per_host):
                w.add(f"s{h}-{i}", bytes([h]) * (100 + i))
        names[str(h)] = shard_file_name(h)
    return names


def test_stitch_shard_directories_totals(tmp_path):
    shard_files = _write_shards(tmp_path, 3)
    stitched = stitch_shard_directories(tmp_path, shard_files)
    assert sorted(stitched["shards"]) == ["0", "1", "2"]
    assert stitched["segments"] == 9
    assert stitched["shards"]["1"]["meta"] == {"host": 1}


def test_stitch_names_torn_shard(tmp_path):
    shard_files = _write_shards(tmp_path, 2)
    (tmp_path / shard_file_name(1)).write_bytes(b"torn")
    with pytest.raises(ContainerError, match="leaves-0001"):
        stitch_shard_directories(tmp_path, shard_files)


def test_shard_set_reader_locality_stats_and_lazy_open(tmp_path):
    shard_files = _write_shards(tmp_path, 2)
    with ShardSetReader(tmp_path, shard_files, local="0") as r:
        assert r.read("0", "s0-0") == b"\x00" * 100
        assert r.stats["local_preads"] == 1 and r.stats["cross_preads"] == 0
        assert r.stats["shards_opened"] == ["0"]  # lazy: shard 1 untouched
        r.read("1", "s1-0")
        assert r.stats["cross_preads"] == 1
        assert r.stats["shards_opened"] == ["0", "1"]
        with pytest.raises(ContainerError, match="no shard"):
            r.read("9", "s0-0")


# ---------------------------------------------------------------------------
# multi-host checkpoint save/restore (threads + explicit topologies)
# ---------------------------------------------------------------------------


def _threaded_save(directory, tree, n_hosts, step=1, policy=None):
    """Run one multi-host save: one manager per simulated host, in threads."""
    policy = policy or CheckpointPolicy(exact=True)
    mgrs = [
        CheckpointManager(directory, policy, topology=HostTopology(h, n_hosts))
        for h in range(n_hosts)
    ]
    manifests: list = [None] * n_hosts
    errs: list = []

    def run(h):
        try:
            manifests[h] = mgrs[h].save(step, tree)
        except Exception as e:
            errs.append(e)

    threads = [threading.Thread(target=run, args=(h,)) for h in range(n_hosts)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    return mgrs, manifests


def test_multihost_save_builds_global_manifest(tmp_path):
    tree = _tree()
    mgrs, manifests = _threaded_save(tmp_path, tree, 2)
    m = manifests[0]
    assert manifests[1] == m  # every host returns the stitched manifest
    assert m["shards"] == {"0": shard_file_name(0), "1": shard_file_name(1)}
    assert m["topology"] == {"hosts": 2}
    assert m["stitched_segments"] == len(m["leaves"])
    assert sorted(m["io"]) == ["0", "1"]
    # every leaf entry names its shard, and both shards hold some leaves
    shards_used = {e["shard"] for e in m["leaves"].values()}
    assert shards_used == {"0", "1"}
    for h in range(2):
        assert (tmp_path / f"step_00000001" / shard_file_name(h)).exists()


def test_multihost_restore_bit_identical_to_single_process(tmp_path):
    tree = _tree()
    _threaded_save(tmp_path / "multi", tree, 2)
    single = CheckpointManager(
        tmp_path / "single", CheckpointPolicy(exact=True),
        topology=HostTopology(0, 1),
    )
    single.save(1, tree)
    # a reader with no locality (fresh single process) sees both layouts
    reader = CheckpointManager(
        tmp_path / "multi", CheckpointPolicy(exact=True),
        topology=HostTopology(0, 1),
    )
    flat_multi, _ = reader.restore(1)
    flat_single, _ = single.restore(1)
    assert sorted(flat_multi) == sorted(flat_single)
    for k in flat_single:
        np.testing.assert_array_equal(flat_multi[k], flat_single[k])
        assert flat_multi[k].dtype == flat_single[k].dtype


def test_same_topology_restore_preads_only_local_shard(tmp_path):
    tree = _tree()
    mgrs, manifests = _threaded_save(tmp_path, tree, 2)
    all_keys = set(manifests[0]["leaves"])
    union = set()
    for h, mgr in enumerate(mgrs):
        flat, _ = mgr.restore(1, leaves="local")
        io = mgr.last_restore_io
        assert io["cross_preads"] == 0
        assert io["shards_opened"] == [str(h)]  # exactly the local shard
        assert io["local_preads"] == len(flat) > 0
        union |= set(flat)
    assert union == all_keys  # locals across hosts cover the checkpoint


def test_remeshed_restore_falls_back_to_cross_shard_preads(tmp_path):
    tree = _tree()
    _threaded_save(tmp_path, tree, 2)
    # restart with a different host count: no locality claim is valid
    remeshed = CheckpointManager(
        tmp_path, CheckpointPolicy(exact=True), topology=HostTopology(0, 3)
    )
    flat, manifest = remeshed.restore(1)
    assert sorted(flat) == sorted(manifest["leaves"])
    io = remeshed.last_restore_io
    assert io["local_preads"] == 0
    assert io["cross_preads"] == len(flat)
    assert sorted(io["shards_opened"]) == ["0", "1"]


def test_corrupt_shard_raises_naming_it_and_healthy_scope_restores(tmp_path):
    tree = _tree()
    mgrs, manifests = _threaded_save(tmp_path, tree, 2)
    m = manifests[0]
    step_dir = tmp_path / "step_00000001"
    # truncate host 1's shard: its trailer no longer parses
    shard1 = step_dir / shard_file_name(1)
    shard1.write_bytes(shard1.read_bytes()[:16])
    with pytest.raises(ContainerError, match="leaves-0001"):
        mgrs[0].restore(1)
    # a restore scoped to the healthy shard's leaves never opens the torn
    # one (lazy shard opening) and succeeds
    healthy = [k for k, e in m["leaves"].items() if e["shard"] == "0"]
    flat, _ = mgrs[0].restore(1, leaves=healthy)
    assert sorted(flat) == sorted(healthy)
    assert mgrs[0].last_restore_io["shards_opened"] == ["0"]


def test_multihost_save_with_fsync_policy(tmp_path):
    tree = _tree()
    _, manifests = _threaded_save(
        tmp_path, tree, 2, policy=CheckpointPolicy(exact=True, fsync=True)
    )
    assert manifests[0]["stitched_segments"] == len(manifests[0]["leaves"])


# ---------------------------------------------------------------------------
# engine-side io-lane routing
# ---------------------------------------------------------------------------


def test_engine_owned_only_drops_remote_leaves():
    tree = _tree()
    with ExecutionEngine(topology=HostTopology(0, 2)) as eng:
        order, _raw, _jobs, stats = eng.encode_leaf_jobs(
            tree, owned_only=True
        )
        topo = eng.topology
        n_leaves = len(order) + stats["remote_leaves"]
        assert stats["remote_leaves"] > 0
        assert all(topo.owns(k) for k in order)
        flat, cstats = eng.compress_pytree(tree, owned_only=True)
        assert sorted(flat) == sorted(order)
        assert cstats["remote_leaves"] == stats["remote_leaves"]
        # default path is unchanged: every leaf, no drops
        full, fstats = eng.compress_pytree(tree)
        assert len(full) == n_leaves and fstats["remote_leaves"] == 0


# ---------------------------------------------------------------------------
# full multi-controller contract: 4 subprocess-simulated hosts
# ---------------------------------------------------------------------------

_HOST_SCRIPT = """
import json, sys
import numpy as np
from repro.checkpoint import CheckpointManager, CheckpointPolicy
from repro.launch.mesh import detect_topology

directory = sys.argv[1]
topo = detect_topology()  # from HPDR_HOST_ID / HPDR_HOST_COUNT
assert topo.n_hosts == 4

rng = np.random.default_rng(7)
tree = {
    "layers": {
        "w%d" % i: rng.normal(size=(24, 8 + i)).astype(np.float32)
        for i in range(6)
    },
    "bias": rng.normal(size=(48,)).astype(np.float32),
    "step": np.int32(3),
}
mgr = CheckpointManager(directory, CheckpointPolicy(exact=True))
manifest = mgr.save(1, tree)
flat, _ = mgr.restore(1, leaves="local")
print(json.dumps({
    "host": topo.host_id,
    "shards": sorted(manifest["shards"]),
    "keys": sorted(flat),
    "io": mgr.last_restore_io,
}))
"""


@pytest.mark.subprocess
def test_four_host_subprocess_save_restore(tmp_path):
    ckpt = tmp_path / "ckpt"
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        str(Path(__file__).resolve().parent.parent / "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    env["HPDR_HOST_COUNT"] = "4"
    procs = []
    for h in range(4):
        env_h = dict(env)
        env_h["HPDR_HOST_ID"] = str(h)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", _HOST_SCRIPT, str(ckpt)],
            env=env_h, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        ))
    reports = []
    for h, p in enumerate(procs):
        out, _ = p.communicate(timeout=480)
        assert p.returncode == 0, f"host {h} failed:\n{out}"
        reports.append(json.loads(out.strip().splitlines()[-1]))

    step_dir = ckpt / "step_00000001"
    assert sorted(p.name for p in step_dir.glob("*.hpdr")) == [
        shard_file_name(h) for h in range(4)
    ]
    union = set()
    for rep in reports:
        assert rep["shards"] == ["0", "1", "2", "3"]
        # same-topology restore: strictly local byte ranges
        assert rep["io"]["cross_preads"] == 0
        assert rep["io"]["shards_opened"] == [str(rep["host"])]
        union |= set(rep["keys"])

    # bit-identity against the single-process path, same tree
    rng = np.random.default_rng(7)
    tree = {
        "layers": {
            f"w{i}": rng.normal(size=(24, 8 + i)).astype(np.float32)
            for i in range(6)
        },
        "bias": rng.normal(size=(48,)).astype(np.float32),
        "step": np.int32(3),
    }
    single = CheckpointManager(
        tmp_path / "single", CheckpointPolicy(exact=True),
        topology=HostTopology(0, 1),
    )
    single.save(1, tree)
    flat_single, _ = single.restore(1)
    assert union == set(flat_single)
    reader = CheckpointManager(
        ckpt, CheckpointPolicy(exact=True), topology=HostTopology(0, 1)
    )
    flat_multi, manifest = reader.restore(1)
    assert manifest["topology"] == {"hosts": 4}
    for k in flat_single:
        np.testing.assert_array_equal(flat_multi[k], flat_single[k])
