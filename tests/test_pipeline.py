"""HDEM pipeline: simulator invariants, adaptive chunking, chunked execution."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import api, chunk_model as cm, pipeline as pl
from conftest import smooth_field_3d


def _phi():
    return cm.PhiModel(alpha=45e9 / (100 << 20), beta0=1e9, gamma=45e9,
                       c_threshold=100 << 20)


def test_simulator_resource_exclusivity():
    rep = pl.simulate_pipeline(1 << 30, "fixed", _phi(), 12e9, 12e9)
    by_res = {}
    for s in rep.schedule.values():
        by_res.setdefault(s.resource, []).append((s.start, s.end))
    for res, ivs in by_res.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert s2 >= e1 - 1e-12, f"overlap on {res}"


def test_simulator_dependencies_respected():
    sizes = [100 << 20] * 5
    dag = pl.build_reduction_dag(
        sizes, lambda c: c / 12e9, lambda c: c / 45e9, lambda c: c / 36e9,
        lambda c: 1e-4,
    )
    sched = pl.TimelineSimulator().run(dag)
    for t in dag:
        for d in t.deps:
            assert sched[d].end <= sched[t.name].start + 1e-12


def test_pipeline_beats_no_pipeline():
    total = 4 << 30
    r_none = pl.simulate_pipeline(total, "none", _phi(), 12e9, 12e9)
    r_fix = pl.simulate_pipeline(total, "fixed", _phi(), 12e9, 12e9)
    assert r_fix.makespan < r_none.makespan  # paper Fig. 13
    assert r_fix.overlap_ratio > r_none.overlap_ratio


def test_adaptive_grows_chunks():
    theta = cm.ThetaModel(beta=1.0 / 12e9)
    sizes = cm.adaptive_chunk_schedule(2 << 30, 16 << 20, 2 << 30, _phi(), theta)
    assert sizes[0] == 16 << 20
    assert max(sizes) > sizes[0]  # grows
    assert sum(sizes) == 2 << 30  # covers everything


def test_phi_fit_recovers_model():
    true = _phi()
    cs = np.array([2**i << 20 for i in range(0, 12)])
    ps = true(cs)
    fit = cm.fit_phi(cs, ps)
    test_c = np.array([8 << 20, 64 << 20, 1 << 30])
    np.testing.assert_allclose(fit(test_c), true(test_c), rtol=0.15)


@settings(max_examples=30, deadline=None)
@given(st.integers(1 << 20, 1 << 30), st.integers(1 << 18, 1 << 24))
def test_fixed_schedule_covers(total, chunk):
    sizes = cm.fixed_chunk_schedule(total, chunk)
    assert sum(sizes) == total
    assert all(s > 0 for s in sizes)
    assert max(sizes) <= chunk


def test_chunked_compress_roundtrip():
    data = smooth_field_3d(32)
    pipe = pl.ChunkedPipeline(
        lambda chunk: api.compress(chunk, "zfp", rate=16),
        mode="fixed", c_fixed_elems=8 * 32 * 32,
    )
    result = pipe.run(data)
    assert len(result.chunks) > 1
    out = pl.decompress_chunked(result, api.decompress)
    assert out.shape == data.shape
    assert np.abs(out - data).max() < 2e-3


def test_reconstruction_launch_order_inversion_has_effect():
    phi = _phi()
    r_def = pl.simulate_pipeline(2 << 30, "fixed", phi, 12e9, 12e9,
                                 reconstruction=True, invert_launch_order=False)
    r_inv = pl.simulate_pipeline(2 << 30, "fixed", phi, 12e9, 12e9,
                                 reconstruction=True, invert_launch_order=True)
    assert r_def.makespan != r_inv.makespan  # ordering is actually modelled


# ---------------------------------------------------------------------------
# lane-overlapped scheduler (PR 5): window bound, overlap, bit-identity
# ---------------------------------------------------------------------------

import threading
import time as _time

import pytest

from repro.core.container import ContainerError
from repro.runtime.executor import COMPUTE, IO, DeviceExecutor


class RecordingExecutor(DeviceExecutor):
    """DeviceExecutor that records one (lane, chunk, start, end) event per
    task — the instrumented fake the scheduling assertions read."""

    def __init__(self):
        super().__init__(max_workers=2, io_workers=1)
        self.events = []
        self._elock = threading.Lock()

    def submit(self, fn, /, *args, device=None, lane=COMPUTE, **kwargs):
        idx = next((a for a in args if isinstance(a, int)), None)

        def task():
            t0 = _time.perf_counter()
            try:
                return fn(*args, **kwargs)
            finally:
                with self._elock:
                    self.events.append((lane, idx, t0, _time.perf_counter()))

        return super().submit(task, device=device, lane=lane)

    def spans(self, lane):
        return {i: (s, e) for (ln, i, s, e) in self.events if ln == lane}


class _StubChunk:
    arrays: dict = {}

    def nbytes(self):
        return 1


def test_compute_overlaps_previous_serialization():
    """Scheduling contract (paper Fig. 9): chunk N's compute runs while
    chunk N-1 serializes.

    Deterministic handshake on an instrumented executor: the io-lane
    serialization of chunk i *blocks* until chunk i+1's compute-lane task
    has started.  Only a genuinely overlapped scheduler can satisfy every
    handshake — a serial schedule (serialize i before staging i+1) would
    time the waits out.  Lane attribution is asserted via the recorded
    events.
    """
    n_chunks, rows, cols = 8, 8, 16
    data = np.arange(n_chunks * rows * cols, dtype=np.float32).reshape(
        n_chunks * rows, cols)
    started = [threading.Event() for _ in range(n_chunks)]
    handshakes = []

    def compute(chunk, slot):
        # chunk content encodes its index (data is an arange)
        idx = int(np.asarray(chunk)[0, 0]) // (rows * cols)
        started[idx].set()
        _time.sleep(0.005)
        return idx

    def finish(idx, slot):
        if idx + 1 < n_chunks:
            ok = started[idx + 1].wait(timeout=10.0)
            handshakes.append((idx, ok))
        return _StubChunk()

    ex = RecordingExecutor()
    try:
        pipe = pl.ChunkedPipeline(
            compute_fn=compute, finish_fn=finish, mode="fixed",
            c_fixed_elems=rows * cols, executor=ex, window=2,
        )
        res = pipe.run(data)
        assert len(res.chunks) == n_chunks
        # serialize(i) saw compute(i+1) already running, for every pair
        assert sorted(i for i, _ok in handshakes) == list(range(n_chunks - 1))
        assert all(ok for _i, ok in handshakes)
        # lane attribution: computes on the compute pool, finishes on io
        comp, ser = ex.spans(COMPUTE), ex.spans(IO)
        assert len(comp) == n_chunks and len(ser) == n_chunks
    finally:
        ex.shutdown()


def test_in_flight_window_is_bounded():
    """No unbounded buffering: staging chunk i waits for chunk i-window to
    fully leave the pipeline, even when serialization is the bottleneck."""
    ex = RecordingExecutor()
    try:
        data = np.arange(12 * 8 * 16, dtype=np.float32).reshape(96, 16)

        def compute(chunk, slot):
            return chunk          # compute much faster than serialize

        def finish(payload, slot):
            _time.sleep(0.02)
            return _StubChunk()

        pipe = pl.ChunkedPipeline(
            compute_fn=compute, finish_fn=finish, mode="fixed",
            c_fixed_elems=8 * 16, executor=ex, window=2,
        )
        res = pipe.run(data)
        assert len(res.chunks) == 12
        assert res.max_in_flight <= 2     # the two-buffer bound
        # the serial schedule degrades to exactly one in flight
        res1 = pl.ChunkedPipeline(
            compute_fn=compute, finish_fn=finish, mode="fixed",
            c_fixed_elems=8 * 16, executor=ex, window=1,
        ).run(data)
        assert res1.max_in_flight == 1
    finally:
        ex.shutdown()


def test_pipelined_stream_bit_identical_to_serial():
    """Acceptance: pipelined CompressorStream bytes == serial bytes, for a
    host-barrier codec (mgard) and a barrier-free one (zfp)."""
    data = smooth_field_3d(32)
    for method, kw in (("zfp", {"rate": 16}),
                       ("mgard", {"error_bound": 1e-2})):
        blobs = []
        for window in (1, 2, 3):
            stream = api.CompressorStream(
                method, mode="fixed", c_fixed_elems=8 * 32 * 32,
                window=window, backend="xla", **kw)
            res = stream.compress(data)
            assert len(res.chunks) > 2
            assert res.max_in_flight <= window
            blobs.append(api.CompressorStream.to_bytes(res))
        assert blobs[0] == blobs[1] == blobs[2], method
        # and identical to the one-shot per-chunk encode (the serial API)
        res = api.CompressorStream.from_bytes(blobs[0])
        first = res.chunks[0]
        chunk0 = data[: res.boundaries[1] if len(res.boundaries) > 1
                      else data.shape[0]]
        serial = api.encode(
            api.make_spec(chunk0, method, backend="xla", **kw), chunk0)
        assert first.to_bytes() == serial.to_bytes()


def test_stream_to_file_preads_only_whats_needed(tmp_path):
    """The aggregated on-disk stream: lazy pread chunks, aligned segments,
    and an old-reader-compatible prefix."""
    data = smooth_field_3d(32)
    stream = api.CompressorStream("zfp", mode="fixed",
                                  c_fixed_elems=8 * 32 * 32, rate=16)
    res = stream.compress(data)
    path = tmp_path / "stream.hpds"
    directory = api.CompressorStream.to_file(res, path, align=512)
    for seg in directory["segments"].values():
        assert seg["offset"] % 512 == 0   # every chunk pread-aligned

    res2 = api.CompressorStream.from_file(path)
    assert res2.chunks.materialized == 0
    first = res2.chunks[0]                # progressive prefix: one pread
    assert res2.chunks.materialized == 1
    assert res2.chunks.reader.preads == 1
    np.testing.assert_array_equal(
        np.asarray(api.decompress(first)), np.asarray(api.decompress(res.chunks[0])))
    out = api.CompressorStream.decompress(res2)
    np.testing.assert_array_equal(out, api.CompressorStream.decompress(res))

    # old readers: the file's byte prefix is a valid HPDS frame
    legacy = api.CompressorStream.from_bytes(path.read_bytes())
    np.testing.assert_array_equal(
        api.CompressorStream.decompress(legacy), out)

    # a plain to_bytes dump (no directory) falls back transparently
    bare = tmp_path / "bare.hpds"
    bare.write_bytes(api.CompressorStream.to_bytes(res))
    res3 = api.CompressorStream.from_file(bare)
    np.testing.assert_array_equal(api.CompressorStream.decompress(res3), out)


def test_stream_compute_failure_propagates():
    """A failing chunk encode surfaces as the original exception, and the
    transient executor shuts down cleanly."""
    def compute(chunk, slot):
        raise RuntimeError("boom")

    pipe = pl.ChunkedPipeline(
        compute_fn=compute, finish_fn=lambda p, s: p, mode="fixed",
        c_fixed_elems=8 * 16,
    )
    with pytest.raises(RuntimeError, match="boom"):
        pipe.run(np.zeros((32, 16), np.float32))
