"""HDEM pipeline: simulator invariants, adaptive chunking, chunked execution."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import api, chunk_model as cm, pipeline as pl
from conftest import smooth_field_3d


def _phi():
    return cm.PhiModel(alpha=45e9 / (100 << 20), beta0=1e9, gamma=45e9,
                       c_threshold=100 << 20)


def test_simulator_resource_exclusivity():
    rep = pl.simulate_pipeline(1 << 30, "fixed", _phi(), 12e9, 12e9)
    by_res = {}
    for s in rep.schedule.values():
        by_res.setdefault(s.resource, []).append((s.start, s.end))
    for res, ivs in by_res.items():
        ivs.sort()
        for (s1, e1), (s2, e2) in zip(ivs, ivs[1:]):
            assert s2 >= e1 - 1e-12, f"overlap on {res}"


def test_simulator_dependencies_respected():
    sizes = [100 << 20] * 5
    dag = pl.build_reduction_dag(
        sizes, lambda c: c / 12e9, lambda c: c / 45e9, lambda c: c / 36e9,
        lambda c: 1e-4,
    )
    sched = pl.TimelineSimulator().run(dag)
    for t in dag:
        for d in t.deps:
            assert sched[d].end <= sched[t.name].start + 1e-12


def test_pipeline_beats_no_pipeline():
    total = 4 << 30
    r_none = pl.simulate_pipeline(total, "none", _phi(), 12e9, 12e9)
    r_fix = pl.simulate_pipeline(total, "fixed", _phi(), 12e9, 12e9)
    assert r_fix.makespan < r_none.makespan  # paper Fig. 13
    assert r_fix.overlap_ratio > r_none.overlap_ratio


def test_adaptive_grows_chunks():
    theta = cm.ThetaModel(beta=1.0 / 12e9)
    sizes = cm.adaptive_chunk_schedule(2 << 30, 16 << 20, 2 << 30, _phi(), theta)
    assert sizes[0] == 16 << 20
    assert max(sizes) > sizes[0]  # grows
    assert sum(sizes) == 2 << 30  # covers everything


def test_phi_fit_recovers_model():
    true = _phi()
    cs = np.array([2**i << 20 for i in range(0, 12)])
    ps = true(cs)
    fit = cm.fit_phi(cs, ps)
    test_c = np.array([8 << 20, 64 << 20, 1 << 30])
    np.testing.assert_allclose(fit(test_c), true(test_c), rtol=0.15)


@settings(max_examples=30, deadline=None)
@given(st.integers(1 << 20, 1 << 30), st.integers(1 << 18, 1 << 24))
def test_fixed_schedule_covers(total, chunk):
    sizes = cm.fixed_chunk_schedule(total, chunk)
    assert sum(sizes) == total
    assert all(s > 0 for s in sizes)
    assert max(sizes) <= chunk


def test_chunked_compress_roundtrip():
    data = smooth_field_3d(32)
    pipe = pl.ChunkedPipeline(
        lambda chunk: api.compress(chunk, "zfp", rate=16),
        mode="fixed", c_fixed_elems=8 * 32 * 32,
    )
    result = pipe.run(data)
    assert len(result.chunks) > 1
    out = pl.decompress_chunked(result, api.decompress)
    assert out.shape == data.shape
    assert np.abs(out - data).max() < 2e-3


def test_reconstruction_launch_order_inversion_has_effect():
    phi = _phi()
    r_def = pl.simulate_pipeline(2 << 30, "fixed", phi, 12e9, 12e9,
                                 reconstruction=True, invert_launch_order=False)
    r_inv = pl.simulate_pipeline(2 << 30, "fixed", phi, 12e9, 12e9,
                                 reconstruction=True, invert_launch_order=True)
    assert r_def.makespan != r_inv.makespan  # ordering is actually modelled
