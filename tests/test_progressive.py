"""Progressive retrieval: telescoping error, prefix decodability, full == MGARD."""

import numpy as np
import jax.numpy as jnp

from repro.core import progressive
from conftest import smooth_field_3d


def test_full_retrieval_meets_bound():
    f = smooth_field_3d(32)
    eb = 1e-2 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb)
    out = np.asarray(progressive.retrieve(stream))
    assert np.abs(out - f).max() <= eb


def test_error_telescopes():
    f = smooth_field_3d(32)
    eb = 1e-3 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb, dict_size=65536)
    curve = progressive.error_curve(stream, f)
    errs = [c["max_err"] for c in curve]
    sizes = [c["bytes"] for c in curve]
    # strictly increasing bytes
    assert all(b > a for a, b in zip(sizes, sizes[1:]))
    # NB: max-norm error is NOT guaranteed monotone per level (MGARD's L2
    # projections can overshoot pointwise mid-hierarchy); the telescoping
    # guarantees are: the full stream meets the bound, and the tail is far
    # below the head.
    assert errs[-1] <= eb
    assert errs[-1] < 0.05 * errs[0]
    # early prefix is much smaller than the whole and still usable
    assert sizes[0] < 0.5 * sizes[-1]


def test_prefix_is_coarse_interpolant():
    """One segment = nodal values only: retrieval equals the coarse-grid
    interpolant of the data up to the quantization bound."""
    f = smooth_field_3d(17)
    eb = 1e-2 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb)
    coarse = np.asarray(progressive.retrieve(stream, 1))
    assert coarse.shape == f.shape
    # the coarse approximation of a smooth field is already usable
    assert np.abs(coarse - f).max() <= 0.75 * float(f.max() - f.min())


def test_segments_decodable_independently():
    f = smooth_field_3d(16)
    eb = 1e-2 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb)
    for n in (1, 2, len(stream.segments)):
        out = np.asarray(progressive.retrieve(stream, n))
        assert np.isfinite(out).all()
