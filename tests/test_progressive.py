"""Progressive tier API: bounds per tier, CMM plan reuse, stream round-trips."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import progressive
from repro.core.context import GLOBAL_CMM
from conftest import smooth_field_3d


def test_full_retrieval_meets_bound():
    f = smooth_field_3d(32)
    eb = 1e-2 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb)
    out = np.asarray(progressive.retrieve(stream))
    assert np.abs(out - f).max() <= eb


def test_every_tier_prefix_meets_its_bound():
    """After loading tiers 0..t the error is within tier_bounds[t] — the
    residual-quantization telescoping contract."""
    f = smooth_field_3d(32)
    eb = 1e-3 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb, tiers=3)
    curve = progressive.error_curve(stream, f)
    assert len(curve) == 3
    for c in curve:
        assert c["max_err"] <= c["bound"]
    sizes = [c["bytes"] for c in curve]
    assert all(b > a for a, b in zip(sizes, sizes[1:]))  # strictly additive
    # the coarse prefix is meaningfully cheaper than the full stream
    assert sizes[0] < sizes[-1]


def test_tier_bounds_ladder():
    bounds = progressive.tier_bounds(1e-4, tiers=3, tier_ratio=8.0)
    assert bounds == [64e-4, 8e-4, 1e-4]
    with pytest.raises(ValueError):
        progressive.tier_bounds(0.0)
    with pytest.raises(ValueError):
        progressive.tier_bounds(1e-3, tiers=0)
    with pytest.raises(ValueError):
        progressive.tier_bounds(1e-3, tier_ratio=1.0)


def test_tiers_for_picks_smallest_sufficient_prefix():
    f = smooth_field_3d(16)
    stream = progressive.refactor(jnp.asarray(f), 1e-4, tiers=3)
    b = stream.tier_bounds
    assert stream.tiers_for(None) == 3
    assert stream.tiers_for(b[0] * 2) == 1
    assert stream.tiers_for(b[1]) == 2
    assert stream.tiers_for(b[2] / 10) == 3  # tighter than finest: all tiers


def test_plans_resolve_through_cmm():
    """refactor/retrieve share one geometry-keyed MGARD plan and one Huffman
    plan per grid size — a second refactor at a *different* bound must add
    zero CMM misses (regression: the legacy path built plan-less executables
    per call)."""
    f = smooth_field_3d(16)
    GLOBAL_CMM.clear()
    h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count

    s1 = progressive.refactor(jnp.asarray(f), 1e-2, tiers=2)
    misses_first = GLOBAL_CMM.miss_count - m0
    assert misses_first >= 1  # plans were built, through the CMM

    s2 = progressive.refactor(jnp.asarray(f), 1e-3, tiers=3)
    progressive.retrieve(s1)
    progressive.retrieve(s2)

    assert GLOBAL_CMM.miss_count == m0 + misses_first  # no new plans
    assert GLOBAL_CMM.hit_count > h0  # later calls were cache hits


def test_stream_bytes_roundtrip():
    f = smooth_field_3d(16)
    eb = 1e-2 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb)
    raw = stream.to_bytes()
    back = progressive.ProgressiveStream.from_bytes(raw)
    assert back.manifest == stream.manifest
    assert back.components == stream.components
    a = np.asarray(progressive.retrieve(stream))
    b = np.asarray(progressive.retrieve(back))
    assert np.array_equal(a, b)


def test_prefix_stream_still_retrieves():
    """A stream holding only a component prefix reconstructs at its bound."""
    f = smooth_field_3d(16)
    eb = 1e-3 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb, tiers=3)
    coarse = progressive.ProgressiveStream(
        manifest=stream.manifest, components=stream.components[:1]
    )
    out = np.asarray(progressive.retrieve(coarse))
    assert np.abs(out - f).max() <= stream.tier_bounds[0]
