"""Refinement conformance suite for progressive multi-precision retrieval.

Three property tiers (hypothesis, or the offline shim from
``_hypothesis_compat``) plus a corruption tier mirroring the aggregated-file
cases in ``test_conformance.py``:

  * **monotone**   — achieved max-error never increases across refinement
    steps, and every prefix honours its tier bound;
  * **bit-identity** — ``retrieve(err)`` + ``refine(err')`` reconstructs the
    exact same array (bit-for-bit) as a fresh reader's direct
    ``retrieve(err')``, for both the aggregated-file and monolithic forms;
  * **prefix-additive bytes** — a refinement chain preads each component
    exactly once: chain total == direct-full total == sum of component
    sizes, strictly less than two independent full retrievals;
  * **corruption** — a damaged component (bit-flip, truncation, tampered
    crc record) raises :class:`ContainerError` naming that component, while
    retrieval at bounds whose prefix excludes it still succeeds; index-less
    old streams without per-section checksums fall back to the whole-payload
    crc on the host.
"""

import json
import tempfile
import zlib
from pathlib import Path

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import container, progressive
from repro.core.container import Compressed, ContainerError
from conftest import smooth_field_3d

# deterministic fields per (size, tiers) example drawn by the properties
SIZES = st.integers(min_value=9, max_value=17)
TIERS = st.integers(min_value=2, max_value=4)


def _field(n: int) -> np.ndarray:
    return smooth_field_3d(int(n))


def _stream(n: int, tiers: int) -> progressive.ProgressiveStream:
    f = _field(n)
    eb = 1e-3 * float(f.max() - f.min())
    return progressive.refactor(jnp.asarray(f), eb, tiers=int(tiers))


# ---------------------------------------------------------------------------
# property tier: monotone refinement
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(SIZES, TIERS)
def test_refinement_error_monotone(n, tiers):
    """Each refinement step tightens (never worsens) the achieved error and
    stays within its tier's advertised bound."""
    f = _field(n)
    stream = _stream(n, tiers)
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "prog.hpdr"
        stream.write(path)
        with progressive.ProgressiveReader(path) as r:
            errs = []
            for k in range(1, r.tiers + 1):
                out = np.asarray(r.refine(tiers=k))
                err = float(np.abs(out - f).max())
                assert err <= r.tier_bounds[k - 1]
                errs.append(err)
    assert all(b <= a for a, b in zip(errs, errs[1:]))  # non-increasing


# ---------------------------------------------------------------------------
# property tier: retrieve + refine ≡ direct retrieve (bit-identical)
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(SIZES, TIERS)
def test_refine_bit_identical_to_direct(n, tiers):
    """retrieve(coarse) then refine(fine) must reproduce a direct
    retrieve(fine) bit-for-bit — same accumulation order, no drift."""
    stream = _stream(n, tiers)
    coarse_err, fine_err = stream.tier_bounds[0], stream.tier_bounds[-1]
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "prog.hpdr"
        stream.write(path)
        with progressive.ProgressiveReader(path) as r:
            r.retrieve(err=coarse_err)
            refined = np.asarray(r.refine(err=fine_err))
        with progressive.ProgressiveReader(path) as direct:
            full = np.asarray(direct.retrieve(err=fine_err))
    assert np.array_equal(refined, full)
    # the monolithic (section-pread) form reconstructs identically too
    mono = progressive.ProgressiveReader.from_bytes(stream.to_bytes())
    mono.retrieve(err=coarse_err)
    assert np.array_equal(np.asarray(mono.refine(err=fine_err)), full)
    # and both match the in-memory whole-stream path
    assert np.array_equal(np.asarray(progressive.retrieve(stream)), full)


# ---------------------------------------------------------------------------
# property tier: bytes fetched are strictly prefix-additive
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(SIZES, TIERS)
def test_bytes_fetched_prefix_additive(n, tiers):
    """A refinement chain never re-reads: each step adds exactly the new
    components' bytes, and the chain total equals one direct full retrieve —
    strictly cheaper than two independent full retrievals."""
    stream = _stream(n, tiers)
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "prog.hpdr"
        stream.write(path)
        with progressive.ProgressiveReader(path) as r:
            seen = 0
            for k in range(1, r.tiers + 1):
                r.refine(tiers=k)
                assert r.preads == k            # one pread per component, ever
                assert r.bytes_fetched == stream.nbytes_upto(k)
                assert r.bytes_fetched > seen   # strictly growing
                seen = r.bytes_fetched
            r.refine(tiers=r.tiers)             # idempotent: no re-read
            assert r.preads == r.tiers
            chain_total = r.bytes_fetched
        with progressive.ProgressiveReader(path) as direct:
            direct.retrieve()
            direct_total = direct.bytes_fetched
    assert chain_total == direct_total == stream.nbytes()
    assert chain_total < 2 * direct_total


# ---------------------------------------------------------------------------
# corruption tier: aggregated segment files
# ---------------------------------------------------------------------------


def _flip_segment_byte(path: Path, directory: dict, name: str) -> None:
    seg = directory["segments"][name]
    raw = bytearray(path.read_bytes())
    raw[int(seg["offset"]) + int(seg["nbytes"]) // 2] ^= 0x01
    path.write_bytes(bytes(raw))


def test_aggregated_component_bitflip_names_component(tmp_path):
    """A flipped byte inside one component fails that component's crc on
    pread — naming it — while bounds whose prefix stops earlier still work."""
    stream = _stream(16, 3)
    path = tmp_path / "prog.hpdr"
    directory = stream.write(path)
    victim = progressive.component_name(2)
    _flip_segment_byte(path, directory, victim)

    with progressive.ProgressiveReader(path) as r:
        coarse = r.retrieve(err=r.tier_bounds[1])      # tiers 0–1: intact
        assert np.isfinite(np.asarray(coarse)).all()
        assert r.tiers_loaded == 2
        with pytest.raises(ContainerError, match="component/00002"):
            r.refine(err=r.tier_bounds[2])
    with progressive.ProgressiveReader(path) as fresh:  # full read also loud
        with pytest.raises(ContainerError, match="crc32"):
            fresh.retrieve()


def test_aggregated_component_crc_tamper(tmp_path):
    """Tampering the *recorded* crc32 in the trailer directory (same-length
    JSON edit) is detected on the segment pread."""
    stream = _stream(12, 2)
    path = tmp_path / "prog.hpdr"
    directory = stream.write(path)
    crc = int(directory["segments"][progressive.component_name(0)]["crc32"])
    raw = path.read_bytes()
    needle = json.dumps(crc).encode()
    tampered = str(crc + 1 if len(str(crc + 1)) == len(str(crc)) else crc - 1)
    idx = raw.rindex(needle)
    path.write_bytes(raw[:idx] + tampered.encode() + raw[idx + len(needle):])

    with progressive.ProgressiveReader(path) as r:
        with pytest.raises(ContainerError, match="component/00000"):
            r.retrieve(tiers=1)


def test_aggregated_component_truncation(tmp_path):
    """Chopping the file mid-way through the last component leaves earlier
    tiers readable; the torn component read raises loudly."""
    stream = _stream(16, 3)
    path = tmp_path / "prog.hpdr"
    directory = stream.write(path)
    last = directory["segments"][progressive.component_name(2)]
    raw = path.read_bytes()
    # keep the trailer directory but gut the last component's tail bytes
    cut_lo = int(last["offset"]) + int(last["nbytes"]) // 2
    cut_hi = int(last["offset"]) + int(last["nbytes"])
    path.write_bytes(raw[:cut_lo] + b"\0" * (cut_hi - cut_lo) + raw[cut_hi:])

    with progressive.ProgressiveReader(path) as r:
        out = r.retrieve(err=r.tier_bounds[1])
        assert np.isfinite(np.asarray(out)).all()
        with pytest.raises(ContainerError, match="component/00002"):
            r.refine()


# ---------------------------------------------------------------------------
# corruption tier: monolithic v2 containers (section preads)
# ---------------------------------------------------------------------------


def _section_extent(raw: bytes, name: str) -> tuple[int, int]:
    header, base = container.peek_header(raw)
    sec = header["sections"][name]
    lo = base + int(sec["offset"])
    return lo, lo + int(sec["nbytes"])


def test_monolithic_component_bitflip_names_component():
    stream = _stream(16, 3)
    raw = bytearray(stream.to_bytes())
    lo, hi = _section_extent(bytes(raw), progressive.component_name(1))
    raw[(lo + hi) // 2] ^= 0x01

    r = progressive.ProgressiveReader.from_bytes(bytes(raw))
    out = r.retrieve(tiers=1)                  # prefix before the damage: fine
    assert np.isfinite(np.asarray(out)).all()
    with pytest.raises(ContainerError, match="component/00001"):
        r.refine(tiers=2)


def test_monolithic_truncation_names_component():
    stream = _stream(12, 2)
    raw = stream.to_bytes()
    lo, _hi = _section_extent(raw, progressive.component_name(1))
    torn = raw[: lo + 4]                       # last component torn mid-blob

    r = progressive.ProgressiveReader.from_bytes(torn)
    assert np.isfinite(np.asarray(r.retrieve(tiers=1))).all()
    with pytest.raises(ContainerError, match="component/00001"):
        r.refine(tiers=2)


def test_indexless_stream_host_fallback():
    """Old v2 streams without per-section crc32 entries: reads fall back to
    one whole-payload verification — intact streams decode, and corruption
    anywhere is reported against the requested component."""
    stream = _stream(12, 2)
    raw = stream.to_bytes()
    header, base = container.peek_header(raw)
    for sec in header["sections"].values():
        sec.pop("crc32", None)                 # simulate a pre-index stream
    hjson = json.dumps(header).encode()
    stripped = (
        raw[:8]
        + np.uint64(len(hjson)).tobytes()
        + hjson
        + raw[base:]
    )

    r = progressive.ProgressiveReader.from_bytes(stripped)
    full = np.asarray(r.retrieve())
    assert np.array_equal(full, np.asarray(progressive.retrieve(stream)))

    flipped = bytearray(stripped)
    flipped[-3] ^= 0x01                        # corrupt somewhere in payload
    r2 = progressive.ProgressiveReader.from_bytes(bytes(flipped))
    with pytest.raises(ContainerError, match="component/00000"):
        r2.retrieve(tiers=1)


def test_non_progressive_stream_rejected():
    c = Compressed(method="mgard", meta={}, arrays={"q": np.zeros(4, np.uint8)})
    with pytest.raises(ContainerError, match="progressive"):
        progressive.ProgressiveReader.from_bytes(c.to_bytes())


# ---------------------------------------------------------------------------
# slow tier: a larger sweep of the same properties
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_large_field_chain_conformance(tmp_path):
    f = smooth_field_3d(40)
    eb = 1e-4 * float(f.max() - f.min())
    stream = progressive.refactor(jnp.asarray(f), eb, tiers=4)
    path = tmp_path / "prog.hpdr"
    stream.write(path)
    with progressive.ProgressiveReader(path) as r:
        prev = None
        for k in range(1, 5):
            out = np.asarray(r.refine(tiers=k))
            err = float(np.abs(out - f).max())
            assert err <= r.tier_bounds[k - 1]
            if prev is not None:
                assert err <= prev
            prev = err
        assert r.preads == 4
        assert r.bytes_fetched == stream.nbytes()
    with progressive.ProgressiveReader(path) as direct:
        assert np.array_equal(np.asarray(direct.retrieve()), out)
