"""Serving engine: batched generation + KV-cache compression roundtrip."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import (
    Request,
    ServingEngine,
    compress_kv_cache,
    decompress_kv_cache,
)

KEY = jax.random.PRNGKey(0)


def _engine(batch=2, max_len=64):
    cfg = get_config("qwen2.5-3b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params, ServingEngine(model, params, batch, max_len)


def test_serve_batched_requests(rng):
    cfg, model, params, eng = _engine()
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                max_new_tokens=6)
        for i in range(4)  # more requests than slots → refill path
    ]
    stats = eng.serve(reqs)
    assert stats["requests"] == 4
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out_tokens)


def test_greedy_decode_is_deterministic(rng):
    cfg, model, params, eng1 = _engine(batch=1)
    _, _, _, eng2 = _engine(batch=1)
    prompt = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    r1 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    r2 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng1.serve([r1])
    eng2.serve([r2])
    assert r1.out_tokens == r2.out_tokens


def test_kv_cache_compression_roundtrip():
    cfg, model, params, eng = _engine()
    cache = model.init_cache(2, 32, jnp.float32)
    # fill with realistic values
    cache = jax.tree.map(
        lambda x: x + 0.1 * jax.random.normal(KEY, x.shape, x.dtype)
        if x.dtype.kind == "f" else x,
        cache,
    )
    comp, stats = compress_kv_cache(cache, rate=16)
    assert stats["ratio"] > 1.5
    restored = decompress_kv_cache(comp, cache)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(cache)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "f" and a.size >= 4096:
            scale = np.abs(b).max() + 1e-9
            assert np.abs(a - b).max() / scale < 2e-3
        else:
            np.testing.assert_array_equal(a, b)
