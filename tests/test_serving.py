"""Serving engine: batched generation + KV-cache compression roundtrip."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import (
    Request,
    ServingEngine,
    compress_kv_cache,
    decompress_kv_cache,
)

KEY = jax.random.PRNGKey(0)


def _engine(batch=2, max_len=64):
    cfg = get_config("qwen2.5-3b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params, ServingEngine(model, params, batch, max_len)


def test_serve_batched_requests(rng):
    cfg, model, params, eng = _engine()
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                max_new_tokens=6)
        for i in range(4)  # more requests than slots → refill path
    ]
    stats = eng.serve(reqs)
    assert stats["requests"] == 4
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out_tokens)


def test_greedy_decode_is_deterministic(rng):
    cfg, model, params, eng1 = _engine(batch=1)
    _, _, _, eng2 = _engine(batch=1)
    prompt = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    r1 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    r2 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng1.serve([r1])
    eng2.serve([r2])
    assert r1.out_tokens == r2.out_tokens


def test_kv_cache_compression_roundtrip():
    cfg, model, params, eng = _engine()
    cache = model.init_cache(2, 32, jnp.float32)
    # fill with realistic values
    cache = jax.tree.map(
        lambda x: x + 0.1 * jax.random.normal(KEY, x.shape, x.dtype)
        if x.dtype.kind == "f" else x,
        cache,
    )
    comp, stats = compress_kv_cache(cache, rate=16)
    assert stats["ratio"] > 1.5
    restored = decompress_kv_cache(comp, cache)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(cache)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "f" and a.size >= 4096:
            scale = np.abs(b).max() + 1e-9
            assert np.abs(a - b).max() / scale < 2e-3
        else:
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# parked-session store: byte-budget LRU eviction + transparent rematerialize
# ---------------------------------------------------------------------------


def _session_cache(seed):
    r = np.random.default_rng(seed)
    return {
        "k": r.normal(size=(2, 4, 64, 8, 16)).astype(np.float32),
        "v": r.normal(size=(2, 4, 64, 8, 16)).astype(np.float32),
        "pos": np.arange(4, dtype=np.int32),
    }


def test_kv_page_store_evicts_and_rematerializes(tmp_path):
    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=600_000, spill_dir=tmp_path, rate=16)
    sessions = {f"s{i}": _session_cache(i) for i in range(4)}
    for sid, cache in sessions.items():
        stats = store.park(sid, cache)
        assert stats["compressed_leaves"] == 2
    st = store.stats()
    # memory pressure: parked bytes stay within budget, LRU sessions spilled
    assert st["parked_bytes"] <= st["capacity_bytes"]
    assert st["spills"] >= 1 and st["evictions"] >= 1
    assert store._path("s0").exists()

    # evicted session rematerializes transparently on access
    restored = store.restore("s0", sessions["s0"])
    np.testing.assert_array_equal(np.asarray(restored["pos"]),
                                  sessions["s0"]["pos"])
    for leaf in ("k", "v"):
        err = np.abs(np.asarray(restored[leaf]) - sessions["s0"][leaf]).max()
        assert err < 1e-2 * np.abs(sessions["s0"][leaf]).max()
    assert store.stats()["loads"] >= 1

    # a still-resident (most recent) session restores without a disk load
    loads_before = store.stats()["loads"]
    store.restore("s3", sessions["s3"])
    assert store.stats()["loads"] == loads_before

    store.release("s0")
    assert not store._path("s0").exists()


def test_kv_page_store_async_and_unknown_session(tmp_path):
    import pytest

    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path, rate=16)
    sub = store.park_async("bg", _session_cache(7))
    stats = sub.result()
    assert stats["compressed_leaves"] == 2
    assert "bg" in str(sorted(k[1] for k in store.cache._entries))
    with pytest.raises(KeyError, match="unknown parked session"):
        store.fetch("never-parked")


def test_kv_page_store_colliding_session_ids_get_distinct_spills(tmp_path):
    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path, rate=16)
    assert store._path("user:1") != store._path("user_1")
    a, b = _session_cache(1), _session_cache(2)
    store.park("user:1", a)
    store.park("user_1", b)
    store.cache.evict(("kv_page", "user:1"))  # force both to spill
    store.cache.evict(("kv_page", "user_1"))
    ra = store.restore("user:1", a)
    rb = store.restore("user_1", b)
    assert not np.allclose(np.asarray(ra["k"]), np.asarray(rb["k"]))
    err = np.abs(np.asarray(ra["k"]) - a["k"]).max()
    assert err < 1e-2 * np.abs(a["k"]).max()
