"""Serving layer: batched generation, KV parking, and the multi-tenant
reduction service (admission, coalescing, quotas, backpressure)."""

import threading
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import (
    Request,
    ServingEngine,
    compress_kv_cache,
    decompress_kv_cache,
)

KEY = jax.random.PRNGKey(0)


def _engine(batch=2, max_len=64):
    cfg = get_config("qwen2.5-3b").smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    return cfg, model, params, ServingEngine(model, params, batch, max_len)


def test_serve_batched_requests(rng):
    cfg, model, params, eng = _engine()
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, 5).astype(np.int32),
                max_new_tokens=6)
        for i in range(4)  # more requests than slots → refill path
    ]
    stats = eng.serve(reqs)
    assert stats["requests"] == 4
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out_tokens)


def test_greedy_decode_is_deterministic(rng):
    cfg, model, params, eng1 = _engine(batch=1)
    _, _, _, eng2 = _engine(batch=1)
    prompt = rng.integers(0, cfg.vocab, 4).astype(np.int32)
    r1 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    r2 = Request(uid=0, prompt=prompt, max_new_tokens=5)
    eng1.serve([r1])
    eng2.serve([r2])
    assert r1.out_tokens == r2.out_tokens


def test_kv_cache_compression_roundtrip():
    cfg, model, params, eng = _engine()
    cache = model.init_cache(2, 32, jnp.float32)
    # fill with realistic values
    cache = jax.tree.map(
        lambda x: x + 0.1 * jax.random.normal(KEY, x.shape, x.dtype)
        if x.dtype.kind == "f" else x,
        cache,
    )
    comp, stats = compress_kv_cache(cache, rate=16)
    assert stats["ratio"] > 1.5
    restored = decompress_kv_cache(comp, cache)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(cache)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype.kind == "f" and a.size >= 4096:
            scale = np.abs(b).max() + 1e-9
            assert np.abs(a - b).max() / scale < 2e-3
        else:
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# parked-session store: byte-budget LRU eviction + transparent rematerialize
# ---------------------------------------------------------------------------


def _session_cache(seed):
    r = np.random.default_rng(seed)
    return {
        "k": r.normal(size=(2, 4, 64, 8, 16)).astype(np.float32),
        "v": r.normal(size=(2, 4, 64, 8, 16)).astype(np.float32),
        "pos": np.arange(4, dtype=np.int32),
    }


def test_kv_page_store_evicts_and_rematerializes(tmp_path):
    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=600_000, spill_dir=tmp_path, rate=16)
    sessions = {f"s{i}": _session_cache(i) for i in range(4)}
    for sid, cache in sessions.items():
        stats = store.park(sid, cache)
        assert stats["compressed_leaves"] == 2
    st = store.stats()
    # memory pressure: parked bytes stay within budget, LRU sessions spilled
    assert st["parked_bytes"] <= st["capacity_bytes"]
    assert st["spills"] >= 1 and st["evictions"] >= 1
    assert store._path("s0").exists()

    # evicted session rematerializes transparently on access
    restored = store.restore("s0", sessions["s0"])
    np.testing.assert_array_equal(np.asarray(restored["pos"]),
                                  sessions["s0"]["pos"])
    for leaf in ("k", "v"):
        err = np.abs(np.asarray(restored[leaf]) - sessions["s0"][leaf]).max()
        assert err < 1e-2 * np.abs(sessions["s0"][leaf]).max()
    assert store.stats()["loads"] >= 1

    # a still-resident (most recent) session restores without a disk load
    loads_before = store.stats()["loads"]
    store.restore("s3", sessions["s3"])
    assert store.stats()["loads"] == loads_before

    store.release("s0")
    assert not store._path("s0").exists()


def test_kv_page_store_async_and_unknown_session(tmp_path):
    import pytest

    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path, rate=16)
    sub = store.park_async("bg", _session_cache(7))
    stats = sub.result()
    assert stats["compressed_leaves"] == 2
    assert "bg" in str(sorted(k[2] for k in store.cache._entries))
    with pytest.raises(KeyError, match="unknown parked session"):
        store.fetch("never-parked")


def test_kv_page_store_colliding_session_ids_get_distinct_spills(tmp_path):
    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path, rate=16)
    assert store._path("user:1") != store._path("user_1")
    a, b = _session_cache(1), _session_cache(2)
    store.park("user:1", a)
    store.park("user_1", b)
    store.cache.evict(("kv_page", "default", "user:1"))  # force both to spill
    store.cache.evict(("kv_page", "default", "user_1"))
    ra = store.restore("user:1", a)
    rb = store.restore("user_1", b)
    assert not np.allclose(np.asarray(ra["k"]), np.asarray(rb["k"]))
    err = np.abs(np.asarray(ra["k"]) - a["k"]).max()
    assert err < 1e-2 * np.abs(a["k"]).max()


# ---------------------------------------------------------------------------
# per-tenant quotas: one tenant's pressure never displaces another tenant
# ---------------------------------------------------------------------------


def test_two_tenant_quota_eviction_ordering(tmp_path):
    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path, rate=16,
                        tenant_quota_bytes={"heavy": 450_000})
    # park in a known order: heavy a0 (oldest) .. a3, light b0
    for i in range(4):
        store.park(f"a{i}", _session_cache(i), tenant="heavy")
    store.park("b0", _session_cache(9), tenant="light")

    st = store.stats()
    # the heavy tenant was trimmed to its quota, LRU-first
    assert st["tenant_bytes"]["heavy"] <= 450_000
    assert st["tenant_evictions"]["heavy"] >= 1
    resident = {k[2] for k in store.cache._entries if k[1] == "heavy"}
    evicted = {f"a{i}" for i in range(4)} - resident
    # eviction ordering: every evicted session is older than every resident
    assert max(int(s[1]) for s in evicted) < min(int(s[1]) for s in resident)
    for sid in evicted:
        assert store._path(sid, "heavy").exists()  # spilled, not lost
    # the light tenant was untouched by the heavy tenant's pressure
    assert "light" not in st["tenant_evictions"]
    loads = store.stats()["loads"]
    store.restore("b0", _session_cache(9), tenant="light")
    assert store.stats()["loads"] == loads  # still resident: no disk load
    # evicted heavy sessions re-materialise transparently
    sid = sorted(evicted)[0]
    restored = store.restore(sid, _session_cache(int(sid[1])), tenant="heavy")
    err = np.abs(np.asarray(restored["k"]) - _session_cache(int(sid[1]))["k"]).max()
    assert err < 1e-2 * np.abs(_session_cache(int(sid[1]))["k"]).max()
    assert store.stats()["loads"] == loads + 1


def test_same_session_id_isolated_across_tenants(tmp_path):
    from repro.serving.engine import KVPageStore

    store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path, rate=16)
    a, b = _session_cache(1), _session_cache(2)
    store.park("shared", a, tenant="t1")
    store.park("shared", b, tenant="t2")
    assert store._path("shared", "t1") != store._path("shared", "t2")
    ra = store.restore("shared", a, tenant="t1")
    rb = store.restore("shared", b, tenant="t2")
    assert not np.allclose(np.asarray(ra["k"]), np.asarray(rb["k"]))


# ---------------------------------------------------------------------------
# park_async / fetch race: readers wait on the in-flight park
# ---------------------------------------------------------------------------


class _GatedIOExecutor:
    """Instrumented executor: io-lane bodies stall until ``gate`` is set."""

    def __init__(self, inner, gate):
        self._inner = inner
        self.gate = gate

    def submit(self, fn, /, *args, lane="compute", **kwargs):
        if lane == "io":
            gate = self.gate

            def gated(*a, **k):
                gate.wait(30)
                return fn(*a, **k)

            return self._inner.submit(gated, *args, lane=lane, **kwargs)
        return self._inner.submit(fn, *args, lane=lane, **kwargs)

    def __getattr__(self, name):
        return getattr(self._inner, name)


def test_park_async_fetch_waits_for_inflight_park(tmp_path):
    from repro.core.engine import ExecutionEngine
    from repro.serving.engine import KVPageStore

    gate = threading.Event()
    with ExecutionEngine(backend="xla") as eng:
        eng.executor = _GatedIOExecutor(eng.executor, gate)
        store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path,
                            rate=16, engine=eng)
        cache = _session_cache(3)
        sub = store.park_async("s", cache)
        got = {}

        def fetcher():
            got["flat"] = store.fetch("s")

        t = threading.Thread(target=fetcher)
        t.start()
        time.sleep(0.15)
        # the park is gated in flight: fetch must wait, not raise KeyError
        assert t.is_alive() and "flat" not in got
        gate.set()
        t.join(30)
        assert not t.is_alive()
        assert sub.result()["compressed_leaves"] == 2
        restored = store.restore("s", cache)
        err = np.abs(np.asarray(restored["k"]) - cache["k"]).max()
        assert err < 1e-2 * np.abs(cache["k"]).max()


def test_park_async_release_waits_for_inflight_park(tmp_path):
    from repro.core.engine import ExecutionEngine
    from repro.serving.engine import KVPageStore

    gate = threading.Event()
    with ExecutionEngine(backend="xla") as eng:
        eng.executor = _GatedIOExecutor(eng.executor, gate)
        store = KVPageStore(capacity_bytes=64 << 20, spill_dir=tmp_path,
                            rate=16, engine=eng)
        sub = store.park_async("s", _session_cache(4))
        released = threading.Event()

        def releaser():
            store.release("s")
            released.set()

        t = threading.Thread(target=releaser)
        t.start()
        time.sleep(0.15)
        assert not released.is_set()  # release waits for the park to land
        gate.set()
        t.join(30)
        sub.result()
        # the release observed the *parked* state and removed it entirely
        with pytest.raises(KeyError):
            store.fetch("s")


# ---------------------------------------------------------------------------
# ReductionService: admission, coalescing, backpressure, metrics
# ---------------------------------------------------------------------------


def _zfp_select(key, arr):
    del key, arr
    return "zfp", {"rate": 16}


def test_service_coalesces_across_requests_with_cmm_hits():
    from repro.core.context import GLOBAL_CMM
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService

    rng = np.random.default_rng(0)
    # a shape this test owns: plan build below is the only CMM miss for it
    trees = [{"w": rng.normal(size=(37, 53)).astype(np.float32)}
             for _ in range(5)]
    with ExecutionEngine(backend="xla") as eng:
        with ReductionService(eng, batch_window=0.05, max_queue=16) as svc:
            misses0 = GLOBAL_CMM.miss_count
            hits0 = GLOBAL_CMM.hit_count
            outs = [None] * len(trees)

            def worker(i):
                outs[i] = svc.compress(trees[i], _zfp_select)

            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(len(trees))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            snap = svc.stats()
        # coalescing engaged: >1 request per stacked bucket, every leaf
        # after the first a real CMM hit (one plan build for the bucket)
        assert snap.stacked_buckets >= 1
        assert snap.batch_fill_ratio > 1.0
        assert snap.coalesced_requests >= 2
        assert GLOBAL_CMM.miss_count - misses0 == 1
        assert GLOBAL_CMM.hit_count - hits0 >= len(trees) - 1
        assert all(o is not None for o in outs)
        assert snap.completed == len(trees)
        assert snap.wait_s_mean >= 0.0


def test_service_overload_reject_and_block_timeout():
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService, ServiceOverloaded

    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(size=(32, 32)).astype(np.float32)}
    gate = threading.Event()

    def stalling_select(key, arr):
        gate.wait(30)  # runs in the dispatcher: deterministically stalls it
        return _zfp_select(key, arr)

    with ExecutionEngine(backend="xla") as eng:
        svc = ReductionService(eng, max_queue=1, overload="reject",
                               batch_window=0.0)
        stalled = svc.submit_compress(tree, stalling_select)
        time.sleep(0.1)  # dispatcher pops `stalled`, stalls inside select
        queued = svc.submit_compress(tree, _zfp_select)  # fills the queue
        with pytest.raises(ServiceOverloaded):
            svc.submit_compress(tree, _zfp_select)
        assert svc.stats().rejected == 1
        gate.set()
        stalled.result()
        queued.result()
        svc.close()

        # block policy with a timeout: admission raises instead of hanging
        gate.clear()
        svc = ReductionService(eng, max_queue=1, overload="block",
                               batch_window=0.0)
        stalled = svc.submit_compress(tree, stalling_select)
        time.sleep(0.1)
        queued = svc.submit_compress(tree, _zfp_select)
        t0 = time.monotonic()
        with pytest.raises(ServiceOverloaded):
            svc.submit_compress(tree, _zfp_select, timeout=0.2)
        assert time.monotonic() - t0 >= 0.2
        gate.set()
        stalled.result()
        queued.result()
        svc.close()


def test_service_overload_shed_drops_oldest():
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService, ServiceOverloaded

    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(size=(32, 32)).astype(np.float32)}
    gate = threading.Event()

    def stalling_select(key, arr):
        gate.wait(30)
        return _zfp_select(key, arr)

    with ExecutionEngine(backend="xla") as eng:
        svc = ReductionService(eng, max_queue=2, overload="shed",
                               batch_window=0.0)
        stalled = svc.submit_compress(tree, stalling_select)
        time.sleep(0.1)
        old = svc.submit_compress(tree, _zfp_select)
        mid = svc.submit_compress(tree, _zfp_select)
        new = svc.submit_compress(tree, _zfp_select)  # sheds `old`
        with pytest.raises(ServiceOverloaded, match="shed"):
            old.result(timeout=5)
        gate.set()
        stalled.result()
        mid.result()
        new.result()  # the newest request survived at the oldest's expense
        assert svc.stats().shed == 1
        svc.close()


def test_service_submit_after_close_raises():
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService

    with ExecutionEngine(backend="xla") as eng:
        svc = ReductionService(eng)
        svc.close()
        svc.close()  # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            svc.submit_compress({"w": np.zeros((8, 8), np.float32)},
                                _zfp_select)


def test_service_bad_request_fails_future_only():
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService

    rng = np.random.default_rng(0)
    with ExecutionEngine(backend="xla") as eng:
        with ReductionService(eng, batch_window=0.0) as svc:
            def broken_select(key, arr):
                raise ValueError("select blew up")

            bad = svc.submit_compress(
                {"w": rng.normal(size=(16, 16)).astype(np.float32)},
                broken_select,
            )
            good = svc.submit_compress(
                {"w": rng.normal(size=(16, 16)).astype(np.float32)},
                _zfp_select,
            )
            with pytest.raises(ValueError, match="select blew up"):
                bad.result(timeout=30)
            flat, stats = good.result(timeout=30)
            assert stats["compressed_leaves"] == 1
            assert svc.stats().failed == 1


@pytest.mark.slow
def test_service_soak_bit_identity_with_direct_api():
    """N client threads, mixed codecs + a per-thread unique-shape leaf:
    every response byte-identical to the direct API, coalesced and
    fallback paths both exercised, decompress round-trips exactly."""
    from repro.core import api
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService

    rng = np.random.default_rng(7)
    n_threads, n_rounds = 6, 3

    def make_tree(i, r):
        return {
            # shared shapes across threads: coalesce into stacked buckets
            "shared_zfp": rng.normal(size=(40, 48)).astype(np.float32),
            "shared_mgard": rng.normal(size=(24, 24)).astype(np.float32),
            # unique shape per (thread, round): mgard keeps the geometry, so
            # each is a singleton spec → exercises the per-leaf fallback
            "unique": rng.normal(size=(8 + i, 9 + r)).astype(np.float32),
            "raw": np.arange(4, dtype=np.int32),  # passthrough
        }

    def select(key, arr):
        if key in ("shared_mgard", "unique"):
            return "mgard", {"error_bound": 1e-2}
        if arr.dtype.kind == "f":
            return "zfp", {"rate": 16}
        return None

    trees = {(i, r): make_tree(i, r)
             for i in range(n_threads) for r in range(n_rounds)}
    with ExecutionEngine(backend="xla") as eng:
        with ReductionService(eng, batch_window=0.02, max_queue=64) as svc:
            outs = {}
            errs = []

            def worker(i):
                try:
                    for r in range(n_rounds):
                        outs[(i, r)] = svc.compress(trees[(i, r)], select)
                except Exception as e:  # pragma: no cover - surfaced below
                    errs.append(e)

            threads = [threading.Thread(target=worker, args=(i,))
                       for i in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            assert not errs
            snap = svc.stats()

            # bit-identity: every container equals the direct API's bytes,
            # coalesced buckets and per-leaf fallbacks alike
            for (i, r), (flat, _stats) in outs.items():
                direct, _ = api.compress_pytree(trees[(i, r)], select,
                                                engine=eng)
                for key, val in direct.items():
                    if isinstance(val, api.Compressed):
                        assert flat[key].to_bytes() == val.to_bytes(), (
                            i, r, key)
                    else:
                        np.testing.assert_array_equal(flat[key], val)

            # decompress through the service matches the direct inverse
            i_r = (0, 0)
            flat, _ = outs[i_r]
            via_svc = svc.decompress(flat, trees[i_r])
            via_api = api.decompress_pytree(flat, trees[i_r], engine=eng)
            for a, b in zip(jax.tree.leaves(via_svc), jax.tree.leaves(via_api)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # both execution shapes ran
        assert snap.stacked_leaves > 0
        assert snap.fallback_leaves > 0
        assert snap.completed == n_threads * n_rounds  # snapshot pre-decompress
        assert snap.batch_fill_ratio > 1.0  # coalescing demonstrably engaged


def test_service_priority_starvation_bound():
    """Regression (PR 10 priority lanes): interactive work admitted *behind*
    a saturating bulk backlog must jump the queue — its p99 wait stays below
    the backlog's — while the starvation bound keeps forcing bulk through
    between interactive dequeues (no bulk lockout)."""
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService

    rng = np.random.default_rng(0)
    tree = {"w": rng.normal(size=(16, 16)).astype(np.float32)}
    dwell = 0.15

    def slow_select(key, arr):
        time.sleep(dwell)  # runs in the dispatcher: one slow bulk dispatch
        return _zfp_select(key, arr)

    with ExecutionEngine(backend="xla") as eng:
        with ReductionService(eng, max_queue=64, batch_window=0.0,
                              max_batch_requests=1,
                              starvation_limit=2) as svc:
            svc.park_kv("starve", {"k": tree["w"]})  # interactive target
            # saturate the bulk lane: 6 dispatch cycles of `dwell` each
            bulk = [svc.submit_compress(tree, slow_select) for _ in range(6)]
            time.sleep(dwell / 2)  # dispatcher is inside bulk[0]'s select
            # interactive arrives LATE, behind the whole bulk backlog
            inter = [svc.submit_fetch_kv("starve") for _ in range(4)]
            for s in inter:
                assert "k" in s.result(timeout=60)
            for s in bulk:
                s.result(timeout=60)
            st = svc.stats()

    pi, pb = st.priorities["interactive"], st.priorities["bulk"]
    assert pi["admitted"] == pi["dispatched"] == 4
    assert pb["dispatched"] == 7  # 6 compresses + the park
    # the histograms exist and carry real samples
    for h in (pi, pb):
        assert h["samples"] >= 1
        assert 0.0 <= h["wait_p50"] <= h["wait_p99"]
        assert h["wait_p99"] <= h["wait_max"] + 1e-9
    # interactive jumped a 5-deep bulk backlog it arrived behind: even its
    # p99 wait undercuts bulk's (which eats the serial `dwell` dispatches)
    assert pi["wait_p99"] < pb["wait_p99"]
    # interactive p99 is bounded by the starvation design: at most one
    # in-progress dispatch + starvation_limit forced-bulk dwells + slack
    assert pi["wait_p99"] < 4 * dwell
    # and the bound engaged: bulk was forced through between interactives
    assert pb["forced"] >= 1
    # executor saw the same tags end-to-end (engine submissions are bulk)
    assert st.executor_priorities.get("bulk", {}).get("submitted", 0) >= 1
