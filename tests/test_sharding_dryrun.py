"""Sharding rules + miniature dry-run on the real device count."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import specs as S
from repro.launch.mesh import make_test_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import hlo_analysis
from repro.runtime import sharding as shr


def test_param_specs_structure():
    cfg = get_config("qwen2.5-3b").smoke()
    mesh = make_test_mesh(1, 1)
    model = build_model(cfg)
    shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    sh = shr.param_shardings(shape, cfg, mesh)
    assert jax.tree.structure(sh) == jax.tree.structure(shape)


def test_divisibility_fallback():
    """Odd dims must fall back to replication, never crash."""
    cfg = get_config("mamba2-370m").smoke()  # vocab 256 smoke, fine
    mesh = make_test_mesh(1, 1)
    model = build_model(cfg)
    shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    report = shr.sharding_report(shape, cfg, mesh)
    assert report["bytes_per_device"] <= report["total_bytes"]


def test_sharding_report_fsdp_shards_more():
    from dataclasses import replace

    cfg = get_config("qwen2.5-3b")
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    # on a 1x1 mesh everything is replicated; this just exercises the paths
    model = build_model(cfg.smoke())
    shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    r1 = shr.sharding_report(shape, replace(cfg.smoke(), fsdp=False), mesh)
    r2 = shr.sharding_report(shape, replace(cfg.smoke(), fsdp=True), mesh)
    assert r2["bytes_per_device"] <= r1["bytes_per_device"]


def test_mini_dryrun_train_lower_compile():
    """Lower+compile a reduced arch's train step on the available devices —
    the in-CI guard for the full 512-device dry-run."""
    cfg = get_config("qwen1.5-4b").smoke()
    mesh = make_test_mesh(1, 1)
    model = build_model(cfg)
    param_sds = S.param_specs(model, mesh)
    opt_cfg = adamw.AdamWConfig()
    opt_sds = S.opt_state_specs(param_sds, mesh, opt_cfg)
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("tiny", 32, 4, "train")
    batch_sds = S.batch_specs(cfg, shape, mesh)
    step = S.make_train_step(model, opt_cfg)
    compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
        param_sds, opt_sds, batch_sds
    ).compile()
    mem = compiled.memory_analysis()
    assert mem is not None
    cost = hlo_analysis.cost_analysis_dict(compiled)
    assert cost.get("flops", 0) > 0


def test_mini_dryrun_decode_lower_compile():
    cfg = get_config("qwen2.5-3b").smoke()
    mesh = make_test_mesh(1, 1)
    model = build_model(cfg)
    param_sds = S.param_specs(model, mesh)
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("tinydec", 64, 4, "decode")
    cache_sds = S.cache_specs(model, shape, mesh)
    tok_sds = S.token_specs(cfg, shape, mesh)
    step = S.make_decode_step(model)
    compiled = jax.jit(step, donate_argnums=(2,)).lower(
        param_sds, tok_sds, cache_sds, jax.ShapeDtypeStruct((), jnp.int32)
    ).compile()
    assert hlo_analysis.cost_analysis_dict(compiled).get("flops", 0) > 0


def test_hlo_collective_parsing_scaled():
    hlo = """
%cond (p: (s32[], f32[4])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}
%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %ar = f32[4]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
}
ENTRY %main (a: f32[4]) -> f32[4] {
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
  ROOT %ag = f32[8]{0} all-gather(%gte2), dimensions={0}
}
"""
    raw = hlo_analysis.parse_collectives(hlo)
    scaled = hlo_analysis.parse_collectives_scaled(hlo)
    assert raw.by_type["all-reduce"].result_bytes == 16
    assert scaled.by_type["all-reduce"].result_bytes == 16 * 12
    assert scaled.by_type["all-gather"].result_bytes == 32  # outside loop ×1


def test_policy_fsdp_dp_and_zero1_compile():
    """The §Perf sharding policies must lower/compile on any mesh size."""
    from dataclasses import replace

    from repro.configs.base import ShapeConfig

    for policy in ("fsdp_dp", "dp_zero1"):
        cfg = replace(get_config("qwen1.5-4b").smoke(), sharding_policy=policy,
                      param_dtype="bfloat16")
        mesh = make_test_mesh(1, 1)
        model = build_model(cfg)
        from repro.launch.mesh import use_mesh

        with use_mesh(mesh):
            param_sds = S.param_specs(model, mesh)
            opt_cfg = adamw.AdamWConfig()
            opt_sds = S.opt_state_specs(param_sds, mesh, opt_cfg, cfg)
            shape = ShapeConfig("tiny", 32, 4, "train")
            batch_sds = S.batch_specs(cfg, shape, mesh)
            step = S.make_train_step(model, opt_cfg)
            compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
                param_sds, opt_sds, batch_sds
            ).compile()
        assert hlo_analysis.cost_analysis_dict(compiled).get("flops", 0) > 0, policy


def test_decode_masked_update_matches_dus(rng):
    """Masked-where cache writes must produce identical decode results."""
    from dataclasses import replace

    import numpy as np

    cfg_a = get_config("qwen2.5-3b").smoke()
    cfg_b = replace(cfg_a, decode_masked_update=True)
    model_a, model_b = build_model(cfg_a), build_model(cfg_b)
    params = model_a.init(jax.random.PRNGKey(0))
    cache_a = model_a.init_cache(2, 8, jnp.float32)
    cache_b = model_b.init_cache(2, 8, jnp.float32)
    tok = jnp.asarray(rng.integers(0, cfg_a.vocab, (2,)), jnp.int32)
    for i in range(4):
        la, cache_a = model_a.decode_step(params, tok, cache_a, jnp.int32(i))
        lb, cache_b = model_b.decode_step(params, tok, cache_b, jnp.int32(i))
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5, atol=1e-5)
        tok = jnp.argmax(la, -1).astype(jnp.int32)
