"""Stage-graph codec pipeline: structure, parity, bit-identity, fan-out.

Covers the PR-3 contracts:
  * graph structure — codecs compile into fused device segments with host
    barriers only at genuine sync points, and intermediates that nothing
    downstream consumes are pruned from segment outputs;
  * device-resident entropy — xla and pallas_interpret produce bit-identical
    streams through the stage pipeline, and the streams equal the historical
    host encoder's on fixed seeds (section-for-section);
  * stacked engine path — MGARD/Huffman buckets now ride the shard_map path
    (one bucket = one executor submission, not one per leaf), bit-identical
    to serial encodes, with CMM counters as in tests/test_engine.py;
  * decode-table caching — repeated decompress calls derive the canonical
    decode tables once per codebook, cached on the CMM plan;
  * transfer accounting — encode fetches are bounded by metadata + the
    compressed stream, never the raw array.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import api, huffman, mgard
from repro.core.codecs import get_codec
from repro.core.context import GLOBAL_CMM
from repro.core.engine import ExecutionEngine
from repro.core.stages import StageGraph, Stage
from conftest import smooth_field_3d


# ---------------------------------------------------------------------------
# graph structure / compilation
# ---------------------------------------------------------------------------


def _pipeline_for(data, method, **params):
    spec = api.make_spec(data, method, **params)
    return api.get_plan(spec).pipeline


def test_codecs_compile_to_expected_segments():
    f = smooth_field_3d(16)
    # zfp: one fused device segment, no host barrier
    assert len(_pipeline_for(f, "zfp", rate=8).device_segments) == 1
    # mgard: decorrelate | quantize+histogram | entropy+pack
    mg = _pipeline_for(f, "mgard", error_bound=1e-2)
    assert [s.name for s in mg.device_segments] == [
        "mgard_decorrelate",
        "uniform_quantize+huffman_histogram",
        "huffman_entropy+bit_pack",
    ]
    # huffman-bytes: histogram front, entropy tail; one host barrier
    hb = _pipeline_for(f, "huffman-bytes")
    assert [s.name for s in hb.device_segments] == [
        "byte_keys+huffman_histogram",
        "huffman_entropy+bit_pack",
    ]


def test_segment_outputs_are_liveness_pruned():
    """(code, length) pairs are consumed by bit_pack inside the same fused
    segment — they must never be segment outputs (device-residency)."""
    f = smooth_field_3d(16)
    for method, kw in (("mgard", {"error_bound": 1e-2}), ("huffman-bytes", {})):
        pipe = _pipeline_for(f, method, **kw)
        tail = pipe.device_segments[-1]
        assert "codes" not in tail.out_keys and "lens" not in tail.out_keys
        assert set(tail.out_keys) >= {"words", "chunk_offsets"}


def test_stage_graph_rejects_undeclared_reads():
    class Bad(Stage):
        name = "bad"
        reads = ("nope",)
        writes = ("x",)

    f = smooth_field_3d(16)
    plan = api.get_plan(api.make_spec(f, "zfp", rate=8))
    with pytest.raises(ValueError, match="no earlier stage produces"):
        StageGraph(stages=(Bad(),), finish_keys=("x",)).compile(plan)


def test_container_records_per_stage_metadata():
    f = smooth_field_3d(16)
    c = api.compress(jnp.asarray(f), "mgard", error_bound=1e-2)
    names = [s["stage"] for s in c.meta["stages"]]
    assert names == ["mgard_decorrelate", "bin_schedule", "uniform_quantize",
                     "huffman_histogram", "codebook_build", "huffman_entropy",
                     "bit_pack"]
    kinds = {s["stage"]: s["kind"] for s in c.meta["stages"]}
    assert kinds["codebook_build"] == "host"
    assert kinds["huffman_entropy"] == "device"
    # the stream (with stage metadata) stays readable by the v2 reader and
    # still writes v1 for compatibility
    for version in (1, 2):
        c2 = api.Compressed.from_bytes(c.to_bytes(version=version))
        np.testing.assert_array_equal(
            np.asarray(api.decompress(c2)), np.asarray(api.decompress(c))
        )


# ---------------------------------------------------------------------------
# device-resident entropy: backend parity + host-encoder bit-identity
# ---------------------------------------------------------------------------


def test_entropy_stage_backend_parity(rng):
    """xla and pallas_interpret runs of the stage pipeline produce
    bit-identical entropy streams (lookup kernel vs jnp gather)."""
    keys = np.minimum(np.abs(rng.normal(0, 30, 20000)).astype(np.int32), 511)
    streams = {}
    for backend in ("xla", "pallas_interpret"):
        c = api.compress(jnp.asarray(keys), "huffman", backend=backend)
        streams[backend] = c.to_bytes()
    assert streams["xla"] == streams["pallas_interpret"]


def test_huffman_stream_bit_identical_to_host_encoder(rng):
    """The device-resident entropy stage reproduces the host encoder's
    stream section-for-section on fixed seeds."""
    keys = np.minimum(np.abs(rng.normal(0, 10, 8192)).astype(np.int32), 255)
    c = api.compress(jnp.asarray(keys), "huffman", backend="xla")
    enc = huffman.compress(jnp.asarray(keys), int(keys.max()) + 1, adapter="xla")
    np.testing.assert_array_equal(c.arrays["words"], np.asarray(enc.words))
    np.testing.assert_array_equal(
        c.arrays["chunk_offsets"], np.asarray(enc.chunk_offsets)
    )
    np.testing.assert_array_equal(c.arrays["length_table"], enc.length_table)
    assert c.meta["total_bits"] == enc.total_bits
    assert c.meta["num_keys"] == enc.num_keys
    assert c.meta["n_symbols"] == enc.n_symbols


def test_mgard_stream_bit_identical_to_host_path():
    f = smooth_field_3d(24)
    c = api.compress(jnp.asarray(f), "mgard", error_bound=1e-2, relative=False,
                     backend="xla")
    obj = mgard.compress(jnp.asarray(f), 1e-2)
    np.testing.assert_array_equal(c.arrays["words"], np.asarray(obj.entropy.words))
    np.testing.assert_array_equal(c.arrays["outlier_idx"], obj.outlier_idx)
    np.testing.assert_array_equal(c.arrays["outlier_val"], obj.outlier_val)
    np.testing.assert_array_equal(c.arrays["bins"], obj.bins)
    assert c.meta["total_bits"] == obj.entropy.total_bits


def test_mgard_outlier_cap_overflow_falls_back(rng):
    """A leaf whose escape count overflows the device compaction cap takes
    the full-fetch fallback and still matches the host oracle."""
    noisy = rng.normal(size=(17, 17)).astype(np.float32) * 100
    spec = api.make_spec(noisy, "mgard", error_bound=1e-6, relative=False,
                         dict_size=16, backend="xla")
    plan = api.get_plan(spec)
    c = api.encode(spec, jnp.asarray(noisy))
    assert len(c.arrays["outlier_idx"]) > plan.meta["out_cap"]
    obj = mgard.compress(jnp.asarray(noisy), 1e-6, dict_size=16)
    np.testing.assert_array_equal(c.arrays["outlier_idx"], obj.outlier_idx)
    np.testing.assert_array_equal(c.arrays["outlier_val"], obj.outlier_val)
    out = np.asarray(api.decode(c))
    assert np.abs(out - noisy).max() <= 1e-4


def test_single_symbol_and_tiny_inputs_roundtrip():
    zeros = np.zeros(777, np.int32)
    c = api.compress(jnp.asarray(zeros), "huffman")
    np.testing.assert_array_equal(np.asarray(api.decompress(c)), zeros)
    one = np.asarray([3.5], np.float32)
    c2 = api.compress(jnp.asarray(one), "huffman-bytes")
    np.testing.assert_array_equal(np.asarray(api.decompress(c2)), one)


# ---------------------------------------------------------------------------
# decode-table caching on the plan (CMM hits for repeated decompress)
# ---------------------------------------------------------------------------


def test_decode_tables_cached_on_plan(rng, monkeypatch):
    keys = np.minimum(np.abs(rng.normal(0, 10, 8192)).astype(np.int32), 127)
    c = api.compress(jnp.asarray(keys), "huffman")
    codec = get_codec("huffman")
    plan = api.get_plan(codec.decode_spec(c))
    for k in [k for k in plan.workspace
              if isinstance(k, str) and k.startswith("decode_tables:")]:
        del plan.workspace[k]

    builds = {"n": 0}
    real = huffman.decode_tables

    def counting(length_table):
        builds["n"] += 1
        return real(length_table)

    monkeypatch.setattr(huffman, "decode_tables", counting)
    h0 = GLOBAL_CMM.hit_count
    out1 = np.asarray(api.decode(c))
    out2 = np.asarray(api.decode(c))
    np.testing.assert_array_equal(out1, keys)
    np.testing.assert_array_equal(out2, keys)
    assert builds["n"] == 1                    # derived once, reused after
    assert GLOBAL_CMM.hit_count >= h0 + 1      # decode plan itself a CMM hit
    cached = [k for k in plan.workspace
              if isinstance(k, str) and k.startswith("decode_tables:")]
    assert len(cached) == 1
    assert plan.nbytes() > 0                   # tables visible to accounting


# ---------------------------------------------------------------------------
# stacked engine path for the formerly host-staged codecs
# ---------------------------------------------------------------------------


def test_engine_mgard_bucket_takes_stacked_path(rng):
    tree = {f"w{i}": rng.normal(size=(48, 64)).astype(np.float32)
            for i in range(4)}
    eng = ExecutionEngine(backend="xla")
    comp, stats = eng.compress_pytree(
        tree, select=lambda k, a: ("mgard", {"error_bound": 1e-2}))
    assert stats["sharded_leaves"] == 4        # no per-leaf future fan-out
    assert eng.stats()["shard_map_calls"] >= 3  # one per fused segment
    for key, arr in tree.items():
        serial = api.compress_leaf(arr, "mgard", error_bound=1e-2, backend="xla")
        assert comp[key].to_bytes() == serial.to_bytes()
    out = eng.decompress_pytree(comp, tree)
    for k in tree:
        vr = tree[k].max() - tree[k].min()
        assert np.abs(np.asarray(out[k]) - tree[k]).max() <= 2e-2 * vr
    eng.close()


def test_engine_huffman_bucket_mixed_alphabets(rng):
    """Int-key leaves with different alphabets share one stacked bucket and
    still produce streams identical to serial encodes (per-leaf codebooks)."""
    tree = {
        f"k{i}": np.minimum(
            np.abs(rng.normal(0, 5 * (i + 1), 4096)).astype(np.int32),
            40 * (i + 1),
        )
        for i in range(3)
    }
    eng = ExecutionEngine(backend="xla")
    comp, stats = eng.compress_pytree(tree, select=lambda k, a: ("huffman", {}))
    assert stats["buckets"] == 1 and stats["sharded_leaves"] == 3
    for key, arr in tree.items():
        serial = api.compress_leaf(arr, "huffman", backend="xla")
        assert comp[key].to_bytes() == serial.to_bytes()
    out = eng.decompress_pytree(comp, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), tree[k])
    eng.close()


@pytest.mark.subprocess
def test_engine_stacked_multidevice_subprocess():
    """Acceptance: on a ≥2-device mesh, MGARD and Huffman buckets execute
    via the stacked shard_map path — one executor submission per bucket
    (not per leaf), one plan build per bucket (CMM counters), streams
    bit-identical to serial.
    """
    if jax.device_count() >= 2:
        pytest.skip("in-process mesh already multi-device; covered inline")
    script = textwrap.dedent("""
        import json
        import numpy as np
        import jax
        from repro.core import api
        from repro.core.context import GLOBAL_CMM
        from repro.core.engine import ExecutionEngine

        rng = np.random.default_rng(0)
        tree = {f"w{i}": rng.normal(size=(48, 64)).astype(np.float32)
                for i in range(8)}
        itree = {f"k{i}": rng.integers(0, 200, 4096).astype(np.int32)
                 for i in range(4)}
        eng = ExecutionEngine(backend="xla")
        GLOBAL_CMM.clear()
        h0, m0 = GLOBAL_CMM.hit_count, GLOBAL_CMM.miss_count
        comp, stats = eng.compress_pytree(
            tree, select=lambda k, a: ("mgard", {"error_bound": 1e-2}))
        submitted_after_mgard = eng.stats()["submitted"]
        comp2, stats2 = eng.compress_pytree(
            itree, select=lambda k, a: ("huffman", {}))
        serial_ok = all(
            comp[k].to_bytes() == api.compress_leaf(
                tree[k], "mgard", error_bound=1e-2, backend="xla").to_bytes()
            for k in tree
        ) and all(
            comp2[k].to_bytes() == api.compress_leaf(
                itree[k], "huffman", backend="xla").to_bytes()
            for k in itree
        )
        out = eng.decompress_pytree(comp2, itree)
        exact = all((np.asarray(out[k]) == itree[k]).all() for k in itree)
        print(json.dumps({
            "devices": jax.device_count(),
            "engine_devices": len(eng.devices),
            "mgard_sharded": stats["sharded_leaves"],
            "huffman_sharded": stats2["sharded_leaves"],
            "submitted_after_mgard": submitted_after_mgard,
            "shard_map_calls": eng.stats()["shard_map_calls"],
            "transfer_d2h": eng.stats()["transfer_d2h"],
            "hits": GLOBAL_CMM.hit_count - h0,
            "misses": GLOBAL_CMM.miss_count - m0,
            "serial_ok": serial_ok,
            "exact": exact,
        }))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = (
        str(Path(__file__).resolve().parent.parent / "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True, text=True,
        timeout=480,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    report = json.loads(proc.stdout.strip().splitlines()[-1])
    assert report["devices"] >= 2 and report["engine_devices"] >= 2
    assert report["mgard_sharded"] == 8        # whole bucket on shard_map
    assert report["huffman_sharded"] == 4
    # the encode hot loop is one whole-mesh submission for the bucket, not
    # one future per leaf
    assert report["submitted_after_mgard"] == 1
    # mgard 3 + huffman 3 encode segments, + 1 fused inverse segment for the
    # stacked huffman decode (decompress_pytree rides shard_map since PR 4)
    assert report["shard_map_calls"] == 3 + 3 + 1
    assert report["transfer_d2h"] > 0
    assert report["serial_ok"] and report["exact"]
    # CMM: one plan build per bucket; every further leaf a real hit
    assert report["misses"] == 2
    assert report["hits"] >= (8 - 1) + (4 - 1)


# ---------------------------------------------------------------------------
# transfer accounting
# ---------------------------------------------------------------------------


def test_encode_transfers_are_metadata_plus_stream(rng):
    """The encode path never stages the raw array back to host: D2H is the
    compressed stream plus metadata-scale barrier fetches."""
    keys = np.minimum(np.abs(rng.normal(0, 6, 1 << 16)).astype(np.int32), 63)
    spec = api.make_spec(keys, "huffman")
    api.encode_profiled(spec, jnp.asarray(keys))  # warm
    c, stage_s, transfers = api.encode_profiled(spec, jnp.asarray(keys))
    assert transfers.d2h < keys.nbytes / 2      # << raw input
    assert transfers.d2h >= c.nbytes() - c.arrays["length_table"].nbytes
    assert set(stage_s) >= {"codebook_build", "huffman_entropy+bit_pack"}
    assert stage_s["codebook_build"] > 0
