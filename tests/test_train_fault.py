"""End-to-end training: loss decreases, checkpoint/restart bit-exactness,
failure injection + resume, elastic resharding, straggler watchdog."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, CheckpointPolicy
from repro.launch.train import train_loop
from repro.runtime import fault

pytestmark = pytest.mark.slow  # model forward passes; excluded from check.sh fast


def test_loss_decreases(tmp_path):
    out = train_loop("qwen2.5-3b", steps=25, batch=4, seq=64, log_every=100)
    assert out["steps_run"] == 25
    assert out["last_loss"] < out["first_loss"]


def test_checkpoint_restart_resumes_exactly(tmp_path):
    ck = str(tmp_path / "ck")
    # run 20 steps with a checkpoint at 10
    full = train_loop("minicpm-2b", steps=20, batch=4, seq=32,
                      ckpt_dir=ck, ckpt_every=10, log_every=100)
    # fresh process-equivalent: restore from step 10 and run to 20
    resumed = train_loop("minicpm-2b", steps=20, batch=4, seq=32,
                         ckpt_dir=ck + "_b", ckpt_every=10, log_every=100,
                         inject_failure_at=None)
    # deterministic data + exact (lossless) checkpoints ⇒ same final loss
    assert abs(full["last_loss"] - resumed["last_loss"]) < 1e-5


def test_failure_injection_and_restart(tmp_path):
    ck = str(tmp_path / "ck")
    # sync checkpoints: the step-10 save must be durably committed before the
    # injected failure (async saves racing a hard crash are *expected* to be
    # lost — the committed-marker protocol just falls back one checkpoint).
    with pytest.raises(RuntimeError, match="injected failure"):
        train_loop("qwen2.5-3b", steps=30, batch=4, seq=32,
                   ckpt_dir=ck, ckpt_every=10, log_every=100,
                   inject_failure_at=15, sync_ckpt=True)
    # restart: auto-restores from step 10 and completes
    out = train_loop("qwen2.5-3b", steps=30, batch=4, seq=32,
                     ckpt_dir=ck, ckpt_every=10, log_every=100)
    assert out["steps_run"] == 20  # resumed from step 10
    assert np.isfinite(out["last_loss"])


def test_skip_nonfinite_update():
    params = {"w": jnp.ones(4)}
    good = {"w": jnp.zeros(4)}
    bad_grads = {"w": jnp.asarray([1.0, jnp.nan, 0.0, 0.0])}
    new, finite = fault.skip_nonfinite_update(good, params, bad_grads)
    assert not bool(finite)
    np.testing.assert_array_equal(np.asarray(new["w"]), np.ones(4))
    ok_grads = {"w": jnp.ones(4)}
    new, finite = fault.skip_nonfinite_update(good, params, ok_grads)
    assert bool(finite)
    np.testing.assert_array_equal(np.asarray(new["w"]), np.zeros(4))


def test_straggler_watchdog():
    w = fault.StragglerWatchdog(threshold=2.0)
    for _ in range(20):
        w.observe(1.0)
    assert w.observe(5.0) is True
    assert w.observe(1.1) is False
    assert w.flagged == 1


def test_preemption_handler_saves(tmp_path):
    import os
    import signal

    saved = []
    fault.install_preemption_handler(lambda: saved.append(True))
    with pytest.raises(SystemExit):
        os.kill(os.getpid(), signal.SIGTERM)
        # signal is sync-delivered in CPython main thread via handler
    assert saved == [True]
