"""Auto-tuner tests: cost-model fits, calibration persistence, tuning.

Covers the PR-7 acceptance criteria:

  * ``fit_phi`` / ``fit_affine`` edge cases (degenerate sweeps, noise,
    saturated/unsaturated profiles, invalid inputs);
  * stub-clock calibration on the fast tier (deterministic, sub-second)
    with in-process and cross-process (``@subprocess``) persistence —
    a warm store performs ZERO measurement sweeps (``SWEEPS_RUN``);
  * tuner decisions against synthetic calibrations (overlap wins on big
    streams, serial degrade on small/overhead-dominated ones);
  * ``simulate_stream`` invariants (window=1 == serial lane sum);
  * CMM plan-key canonicalisation: ``chunk_size="auto"`` resolving to N
    hits the SAME cached plans as an explicit ``chunk_size=N``;
  * the small-payload regression: tiny streams auto-degrade to window=1
    and never lose to the serial schedule;
  * auto/explicit bit-identity end-to-end (stream, service, checkpoint).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import api, chunk_model as cm, tuner
from repro.core.context import GLOBAL_CMM
from repro.runtime import calibrate, roofline


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------


@pytest.fixture
def cal_dir(tmp_path):
    """Isolate calibration persistence in a per-test directory."""
    calibrate.set_calibration_dir(tmp_path)
    yield tmp_path
    calibrate.set_calibration_dir(None)


def _synthetic_cal(method="zfp", dtype="float32", *, gamma=2e9,
                   h2d_t0=1e-5, ser_t0=2e-5) -> calibrate.MethodCalibration:
    phi = cm.PhiModel(alpha=gamma / (1 << 20), beta0=gamma * 0.05,
                      gamma=gamma, c_threshold=1 << 20)
    return calibrate.MethodCalibration(
        method=method, dtype=dtype, phi=phi,
        h2d=cm.AffineCost(t0=h2d_t0, bps=5e9),
        serialize=cm.AffineCost(t0=ser_t0, bps=3e9),
        output_fraction=0.5,
    )


def _seed_store(method="zfp", dtype="float32", **kw):
    """Inject a synthetic calibration so no measurement sweep ever runs."""
    store = calibrate.load_store(None)
    mc = _synthetic_cal(method, dtype, **kw)
    store.methods[calibrate.method_key(method, dtype)] = mc
    if store.window_overhead_s is None:
        store.window_overhead_s = 1e-5
    if store.host_frame_bps is None:
        store.host_frame_bps = 1e9
    return mc


# ---------------------------------------------------------------------------
# fit_phi edge cases
# ---------------------------------------------------------------------------


def test_fit_phi_empty_raises():
    with pytest.raises(ValueError, match="empty sweep"):
        cm.fit_phi(np.array([]), np.array([]))


def test_fit_phi_mismatched_raises():
    with pytest.raises(ValueError, match="must align"):
        cm.fit_phi(np.array([1.0, 2.0]), np.array([1.0]))


def test_fit_phi_nonfinite_and_nonpositive_raise():
    with pytest.raises(ValueError, match="finite"):
        cm.fit_phi(np.array([1.0, np.nan]), np.array([1.0, 2.0]))
    with pytest.raises(ValueError, match="> 0"):
        cm.fit_phi(np.array([1.0, 2.0]), np.array([1.0, -2.0]))


def test_fit_phi_single_point_flat_model():
    phi = cm.fit_phi(np.array([4096.0]), np.array([1e8]))
    assert phi.alpha == 0.0 and phi.gamma == 1e8
    assert phi(1) == pytest.approx(1e8)
    assert phi(1 << 30) == pytest.approx(1e8)


def test_fit_phi_two_points_fits_line():
    phi = cm.fit_phi(np.array([1e3, 2e3]), np.array([1e6, 2e6]))
    assert phi.alpha > 0
    assert phi(1.5e3) == pytest.approx(1.5e6, rel=1e-6)


def test_fit_phi_all_saturated_profile():
    c = np.array([1e4, 1e5, 1e6, 1e7])
    p = np.full(4, 3e9)
    phi = cm.fit_phi(c, p)
    assert phi.alpha == 0.0
    for x in (1e3, 1e6, 1e9):
        assert phi(x) == pytest.approx(3e9)


def test_fit_phi_all_unsaturated_profile():
    # still rising at the largest chunk: knee placed at the sweep edge
    c = np.array([1e4, 1e5, 1e6, 1e7])
    p = 10.0 * c + 1e5
    phi = cm.fit_phi(c, p)
    assert phi.alpha == pytest.approx(10.0, rel=1e-3)
    assert phi.c_threshold == pytest.approx(1e7)


def test_fit_phi_noisy_nonmonotone_still_valid():
    rng = np.random.default_rng(7)
    c = np.array([1e4, 3e4, 1e5, 3e5, 1e6])
    p = np.abs(1e9 + 5e8 * rng.standard_normal(5)) + 1.0
    phi = cm.fit_phi(c, p)
    assert np.isfinite(phi.gamma) and phi.gamma > 0
    assert np.all(np.isfinite(phi(c))) and np.all(phi(c) > 0)
    assert phi.time_for(1e6) > 0


# ---------------------------------------------------------------------------
# fit_affine
# ---------------------------------------------------------------------------


def test_fit_affine_recovers_exact_model():
    truth = cm.AffineCost(t0=2e-4, bps=1e9)
    c = np.array([1e4, 1e5, 1e6, 1e7])
    t = np.array([truth.time_for(x) for x in c])
    fit = cm.fit_affine(c, t)
    assert fit.t0 == pytest.approx(2e-4, rel=1e-6)
    assert fit.bps == pytest.approx(1e9, rel=1e-6)


def test_fit_affine_single_point_secant():
    fit = cm.fit_affine(np.array([1e6]), np.array([1e-3]))
    assert fit.t0 == 0.0 and fit.bps == pytest.approx(1e9)


def test_fit_affine_negative_slope_falls_back():
    fit = cm.fit_affine(np.array([1e4, 1e6]), np.array([2e-3, 1e-3]))
    assert fit.t0 == 0.0 and fit.bps == pytest.approx(1e9)


def test_fit_affine_invalid_raises():
    with pytest.raises(ValueError):
        cm.fit_affine(np.array([]), np.array([]))
    with pytest.raises(ValueError):
        cm.fit_affine(np.array([1.0]), np.array([-1.0]))


# ---------------------------------------------------------------------------
# simulate_stream invariants
# ---------------------------------------------------------------------------


def _linear(bps):
    return lambda c: c / bps


def test_simulate_stream_window1_equals_serial_sum():
    sizes = [1000, 2000, 3000]
    mk, _ = roofline.simulate_stream(
        sizes, _linear(1e6), _linear(2e6), _linear(3e6), window=1)
    expect = sum(c / 1e6 + c / 2e6 + c / 3e6 for c in sizes)
    assert mk == pytest.approx(expect, rel=1e-9)


def test_simulate_stream_overlap_never_slower_without_overhead():
    sizes = [4096] * 8
    mk1, _ = roofline.simulate_stream(
        sizes, _linear(1e6), _linear(1e6), _linear(1e6), window=1)
    mk2, _ = roofline.simulate_stream(
        sizes, _linear(1e6), _linear(1e6), _linear(1e6), window=2)
    assert mk2 <= mk1 + 1e-12
    # balanced lanes, deep stream: overlap should win decisively
    assert mk2 < 0.6 * mk1


def test_simulate_stream_window_overhead_charged_only_when_pipelined():
    sizes = [4096] * 4
    base, _ = roofline.simulate_stream(
        sizes, _linear(1e6), _linear(1e6), _linear(1e6), window=1,
        window_overhead_s=1.0)
    nofee, _ = roofline.simulate_stream(
        sizes, _linear(1e6), _linear(1e6), _linear(1e6), window=1)
    assert base == pytest.approx(nofee)  # serial pays no pipelining fee
    fee, _ = roofline.simulate_stream(
        sizes, _linear(1e6), _linear(1e6), _linear(1e6), window=2,
        window_overhead_s=1.0)
    assert fee > nofee  # huge fee makes window=2 strictly worse


# ---------------------------------------------------------------------------
# tuner decisions on synthetic calibrations
# ---------------------------------------------------------------------------


def test_plan_stream_overlap_wins_on_deep_stream():
    cal = _synthetic_cal()
    plan = tuner.plan_stream(
        1 << 22, 4, method="zfp", calibration=cal, window_overhead_s=0.0)
    assert plan.source == "calibrated"
    assert plan.window > 1
    assert plan.n_chunks > tuner.SERIAL_CHUNK_FLOOR
    assert plan.predicted_s <= plan.predicted_serial_s


def test_plan_stream_small_payload_degrades_to_serial():
    cal = _synthetic_cal()
    plan = tuner.plan_stream(
        1024, 4, method="zfp", calibration=cal, window_overhead_s=0.0)
    # payload fits in <= SERIAL_CHUNK_FLOOR chunks at the minimum chunk
    # size: pipelining is pinned off
    assert plan.window == 1


def test_plan_stream_huge_overhead_degrades_to_serial():
    cal = _synthetic_cal()
    plan = tuner.plan_stream(
        1 << 22, 4, method="zfp", calibration=cal, window_overhead_s=10.0)
    assert plan.window == 1
    assert plan.predicted_s == pytest.approx(plan.predicted_serial_s)


def test_plan_stream_pinned_chunk_respected():
    cal = _synthetic_cal()
    plan = tuner.plan_stream(
        1 << 20, 4, method="zfp", calibration=cal,
        chunk_elems=1 << 16, window_overhead_s=0.0)
    assert plan.chunk_elems == 1 << 16


def test_plan_stream_heuristic_fallback_without_method(cal_dir):
    plan = tuner.plan_stream(1 << 20, 4, method=None)
    assert plan.source == "heuristic"
    assert plan.n_chunks >= 1
    tiny = tuner.plan_stream(256, 4, method=None)
    assert tiny.window == 1


def test_plan_stream_deterministic():
    cal = _synthetic_cal()
    plans = {
        tuner.plan_stream(3_000_000, 4, method="zfp", calibration=cal,
                          window_overhead_s=1e-5)
        for _ in range(5)
    }
    assert len(plans) == 1


def test_candidate_race_converges_on_measured_winner(cal_dir):
    """Store-backed full-auto specs race top-K candidates, then pin the
    measured winner — even when the model mis-ranked them."""
    _seed_store("zfp")
    total, itemsize = 1 << 20, 4

    def solve():
        return tuner.plan_stream(total, itemsize, method="zfp",
                                 dtype="float32")

    first = solve()
    assert first.source == "calibrated"
    # without feedback the plan is stable: always the model's argmin
    assert solve().to_dict() == first.to_dict()

    # drive the race: report every explored candidate as slow EXCEPT one
    # the model did NOT rank first — the race must pin that one
    seen = []
    winner = None
    for _ in range(tuner._EXPLORE_K * tuner._EXPLORE_RUNS):
        plan = solve()
        cand = (plan.chunk_elems, plan.window)
        if cand not in seen:
            seen.append(cand)
        fake_wall = plan.predicted_raw_s * (0.5 if len(seen) >= 2 and
                                            cand == seen[1] else 2.0)
        if len(seen) >= 2 and cand == seen[1]:
            winner = cand
        tuner.observe(plan, total, itemsize, fake_wall)
    assert len(seen) >= 2  # it really explored distinct candidates
    settled = solve()
    assert (settled.chunk_elems, settled.window) == winner
    # the exploit plan's prediction is the winner's best-achieved wall
    follow = solve()
    assert follow.predicted_s == settled.predicted_s
    # a better observation un-pins the cache and re-ranks
    tuner.observe(settled, total, itemsize, settled.predicted_s * 0.5)
    assert solve().predicted_s == pytest.approx(settled.predicted_s * 0.5)


# ---------------------------------------------------------------------------
# calibration: stub-clock measurement + persistence
# ---------------------------------------------------------------------------


class _StubClock:
    """Deterministic monotone clock: every call advances 1 ms."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1e-3
        return self.t


def test_stub_clock_calibration_fast_and_persisted(cal_dir):
    sweeps0 = calibrate.SWEEPS_RUN
    mc = calibrate.get_method_calibration(
        "zfp", "float32", params={"rate": 16}, clock=_StubClock(),
        best_of=1, sweep_elems=(2 << 10, 4 << 10),
    )
    assert mc is not None
    assert calibrate.SWEEPS_RUN > sweeps0  # this process really measured
    assert np.isfinite(mc.phi.gamma) and mc.phi.gamma > 0
    assert mc.h2d.bps > 0 and mc.serialize.bps > 0
    assert 0 < mc.output_fraction < 4
    path = calibrate.calibration_path()
    assert path.exists()
    d = json.loads(path.read_text())
    assert d["version"] == calibrate.CALIBRATION_VERSION
    assert d["machine"] == calibrate.machine_key()
    assert calibrate.method_key("zfp", "float32") in d["methods"]

    # same-process reload from disk: zero additional sweeps
    calibrate.set_calibration_dir(cal_dir)  # clears the in-proc store cache
    sweeps1 = calibrate.SWEEPS_RUN
    mc2 = calibrate.get_method_calibration("zfp", "float32")
    assert calibrate.SWEEPS_RUN == sweeps1
    assert mc2 is not None and mc2.phi.gamma == pytest.approx(mc.phi.gamma)
    assert calibrate.load_store().loaded_from_disk


def test_calibration_invalidated_on_version_mismatch(cal_dir):
    _seed_store()
    calibrate.load_store().save()
    path = calibrate.calibration_path()
    d = json.loads(path.read_text())
    d["version"] = calibrate.CALIBRATION_VERSION + 1
    path.write_text(json.dumps(d))
    calibrate.set_calibration_dir(cal_dir)
    mc = calibrate.get_method_calibration("zfp", "float32", measure=False)
    assert mc is None  # stale version ignored, nothing measured


def test_calibration_invalidated_on_machine_mismatch(cal_dir):
    _seed_store()
    calibrate.load_store().save()
    path = calibrate.calibration_path()
    d = json.loads(path.read_text())
    d["machine"] = "someone_elses_gpu_x8_cuda"
    path.write_text(json.dumps(d))
    calibrate.set_calibration_dir(cal_dir)
    assert calibrate.get_method_calibration(
        "zfp", "float32", measure=False) is None


@pytest.mark.subprocess
def test_calibration_persists_across_processes(tmp_path):
    """Process 1 calibrates and persists; process 2 loads with 0 sweeps."""
    env = dict(os.environ)
    env["HPDR_CALIBRATION_DIR"] = str(tmp_path)
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")

    measure = (
        "from repro.runtime import calibrate\n"
        "mc = calibrate.get_method_calibration(\n"
        "    'zfp', 'float32', params={'rate': 16}, best_of=1,\n"
        "    sweep_elems=(2 << 10, 4 << 10))\n"
        "assert mc is not None\n"
        "print('SWEEPS', calibrate.SWEEPS_RUN)\n"
    )
    out1 = subprocess.run(
        [sys.executable, "-c", measure], env=env, capture_output=True,
        text=True, check=True,
    ).stdout
    assert "SWEEPS" in out1
    assert int(out1.strip().split()[-1]) >= 1

    load = (
        "from repro.runtime import calibrate\n"
        "mc = calibrate.get_method_calibration('zfp', 'float32')\n"
        "assert mc is not None\n"
        "assert calibrate.load_store().loaded_from_disk\n"
        "print('SWEEPS', calibrate.SWEEPS_RUN)\n"
    )
    out2 = subprocess.run(
        [sys.executable, "-c", load], env=env, capture_output=True,
        text=True, check=True,
    ).stdout
    assert int(out2.strip().split()[-1]) == 0  # warm load: zero sweeps


def test_race_winner_persisted_and_seeds_warm_start(cal_dir):
    """A converged race's winner lands in the calibration store, and a
    warm start (cleared tuner caches, same store) pins it with zero
    exploration races."""
    from repro.runtime import calibrate

    _seed_store("zfp")
    total, itemsize = 1 << 20, 4

    def solve():
        return tuner.plan_stream(total, itemsize, method="zfp",
                                 dtype="float32")

    seen = []
    for _ in range(tuner._EXPLORE_K * tuner._EXPLORE_RUNS):
        plan = solve()
        cand = (plan.chunk_elems, plan.window)
        if cand not in seen:
            seen.append(cand)
        fast = len(seen) >= 2 and cand == seen[1]
        tuner.observe(plan, total, itemsize,
                      plan.predicted_raw_s * (0.5 if fast else 2.0))
    settled = solve()  # exploit step: pins AND persists the winner
    rec = calibrate.get_race_winner("zfp", "float32", total, itemsize)
    assert rec is not None
    assert (rec["chunk_elems"], rec["window"]) == (settled.chunk_elems,
                                                  settled.window)
    assert rec["measured_s"] > 0

    # simulate a fresh process: same store dir, all tuner caches dropped
    calibrate.set_calibration_dir(cal_dir)
    _seed_store("zfp")
    started = tuner.RACES_STARTED
    warm = solve()
    assert (warm.chunk_elems, warm.window) == (settled.chunk_elems,
                                               settled.window)
    assert tuner.RACES_STARTED == started  # seeded race, no exploration


@pytest.mark.subprocess
def test_race_winner_persists_across_processes(tmp_path):
    """Process 1 races candidates and persists the winner; process 2 starts
    from the raced winner with zero new races."""
    env = dict(os.environ)
    env["HPDR_CALIBRATION_DIR"] = str(tmp_path)
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")

    seed = (
        "from repro.core import chunk_model as cm, tuner\n"
        "from repro.runtime import calibrate\n"
        "store = calibrate.load_store(None)\n"
        "phi = cm.PhiModel(alpha=2e9 / (1 << 20), beta0=2e9 * 0.05,\n"
        "                  gamma=2e9, c_threshold=1 << 20)\n"
        "store.methods[calibrate.method_key('zfp', 'float32')] = (\n"
        "    calibrate.MethodCalibration(\n"
        "        method='zfp', dtype='float32', phi=phi,\n"
        "        h2d=cm.AffineCost(t0=1e-5, bps=5e9),\n"
        "        serialize=cm.AffineCost(t0=2e-5, bps=3e9),\n"
        "        output_fraction=0.5))\n"
        "store.window_overhead_s = 1e-5\n"
        "store.host_frame_bps = 1e9\n"
        "total, itemsize = 1 << 20, 4\n"
    )
    race = seed + (
        "seen = []\n"
        "for _ in range(tuner._EXPLORE_K * tuner._EXPLORE_RUNS):\n"
        "    plan = tuner.plan_stream(total, itemsize, method='zfp',\n"
        "                             dtype='float32')\n"
        "    cand = (plan.chunk_elems, plan.window)\n"
        "    if cand not in seen:\n"
        "        seen.append(cand)\n"
        "    fast = len(seen) >= 2 and cand == seen[1]\n"
        "    tuner.observe(plan, total, itemsize,\n"
        "                  plan.predicted_raw_s * (0.5 if fast else 2.0))\n"
        "plan = tuner.plan_stream(total, itemsize, method='zfp',\n"
        "                         dtype='float32')\n"
        "rec = calibrate.get_race_winner('zfp', 'float32', total, itemsize)\n"
        "assert rec is not None\n"
        "print('WINNER', plan.chunk_elems, plan.window, tuner.RACES_STARTED)\n"
    )
    out1 = subprocess.run(
        [sys.executable, "-c", race], env=env, capture_output=True,
        text=True, check=True,
    ).stdout
    _, ce1, w1, started1 = out1.strip().splitlines()[-1].split()
    assert int(started1) >= 1  # the cold process really raced

    warm = seed + (
        "plan = tuner.plan_stream(total, itemsize, method='zfp',\n"
        "                         dtype='float32')\n"
        "print('WINNER', plan.chunk_elems, plan.window, tuner.RACES_STARTED)\n"
    )
    out2 = subprocess.run(
        [sys.executable, "-c", warm], env=env, capture_output=True,
        text=True, check=True,
    ).stdout
    _, ce2, w2, started2 = out2.strip().splitlines()[-1].split()
    assert (ce2, w2) == (ce1, w1)  # warm process starts at the raced winner
    assert int(started2) == 0  # ...with zero exploration races


# ---------------------------------------------------------------------------
# auto wiring: CMM canonicalisation, bit-identity, small-payload guard
# ---------------------------------------------------------------------------


def _stream_auto(data, **params):
    s = api.CompressorStream("zfp", chunk_size="auto", window="auto",
                             frame=True, **params)
    return s, s.compress(data)


def test_auto_chunk_hits_same_cmm_plans_as_explicit(cal_dir):
    _seed_store("zfp")
    rng = np.random.default_rng(3)
    data = rng.normal(size=(64, 31, 29)).astype(np.float32)

    _, res_auto = _stream_auto(data, rate=16)
    assert res_auto.tuned is not None
    chunk_elems = res_auto.tuned["chunk_elems"]

    # the auto run built (or reused) every per-chunk plan; the SAME
    # explicit chunk size must now be pure CMM hits — the resolved chunk
    # never enters the plan key
    misses0 = GLOBAL_CMM.miss_count
    hits0 = GLOBAL_CMM.hit_count
    explicit = api.CompressorStream(
        "zfp", mode="fixed", c_fixed_elems=chunk_elems,
        window=res_auto.window, frame=True, rate=16)
    res_exp = explicit.compress(data)
    assert GLOBAL_CMM.miss_count == misses0
    assert GLOBAL_CMM.hit_count > hits0
    # and the wire bytes are identical
    assert (api.CompressorStream.to_bytes(res_auto)
            == api.CompressorStream.to_bytes(res_exp))


def test_small_payload_auto_degrades_to_serial(cal_dir):
    _seed_store("zfp")
    rng = np.random.default_rng(4)
    tiny = rng.normal(size=(4, 16, 16)).astype(np.float32)  # 4 KB

    auto_stream = api.CompressorStream("zfp", chunk_size="auto",
                                       window="auto", frame=True, rate=16)
    res = auto_stream.compress(tiny)
    assert res.window == 1  # regression BENCH_pipeline.json small-payload

    # wall-clock guard: auto must not lose to the explicit serial run.
    # Interleave the runs so scheduler drift cannot bias one side of a
    # sub-millisecond comparison; retry once — both streams execute the
    # identical schedule, so a miss is measurement noise, and two
    # independent misses would mean a real regression.
    serial = api.CompressorStream(
        "zfp", mode="fixed", c_fixed_elems=res.tuned["chunk_elems"],
        window=1, frame=True, rate=16)

    def best_walls(n=9):
        auto_walls, serial_walls = [], []
        for _ in range(n):
            auto_walls.append(auto_stream.compress(tiny).wall_time)
            serial_walls.append(serial.compress(tiny).wall_time)
        return min(auto_walls), min(serial_walls)

    a, s = best_walls()
    if a > s * 1.05:
        a, s = best_walls()
    assert a <= s * 1.05


def test_auto_bit_identical_to_serial_and_windowed(cal_dir):
    _seed_store("zfp")
    rng = np.random.default_rng(5)
    data = rng.normal(size=(48, 24, 24)).astype(np.float32)
    _, res_auto = _stream_auto(data, rate=16)
    chunk_elems = res_auto.tuned["chunk_elems"]
    blobs = {api.CompressorStream.to_bytes(res_auto)}
    for w in (1, 2):
        s = api.CompressorStream("zfp", mode="fixed",
                                 c_fixed_elems=chunk_elems, window=w,
                                 frame=True, rate=16)
        blobs.add(api.CompressorStream.to_bytes(s.compress(data)))
    assert len(blobs) == 1  # one wire format regardless of schedule
    out = api.CompressorStream.decompress(res_auto)
    assert out.shape == data.shape


@pytest.mark.slow  # cross-layer integration: full tier only, keeps `fast` <1min
def test_engine_stream_defaults_to_auto(cal_dir):
    from repro.core.engine import ExecutionEngine

    _seed_store("huffman-bytes")
    rng = np.random.default_rng(6)
    data = rng.normal(size=(32, 16, 16)).astype(np.float32)
    with ExecutionEngine(backend="xla") as eng:
        stream = eng.stream("huffman-bytes")
        res = stream.compress(data)
    assert res.tuned is not None
    assert res.tuned["source"] in ("calibrated", "heuristic")
    np.testing.assert_array_equal(
        api.CompressorStream.decompress(res), data)


@pytest.mark.slow  # cross-layer integration: full tier only, keeps `fast` <1min
def test_service_stream_roundtrip_and_stats(cal_dir):
    from repro.core.engine import ExecutionEngine
    from repro.serving import ReductionService

    _seed_store("huffman-bytes")
    rng = np.random.default_rng(8)
    data = rng.normal(size=(32, 24, 24)).astype(np.float32)
    with ExecutionEngine(backend="xla") as eng:
        with ReductionService(eng, batch_window=0.0) as svc:
            blob, info = svc.compress_stream(data, "huffman-bytes")
            snap = svc.stats()
    assert snap.stream_requests == 1
    assert info["chunks"] >= 1 and info["window"] >= 1
    res = api.CompressorStream.from_bytes(blob)
    out = api.CompressorStream.decompress(res)
    np.testing.assert_array_equal(out, data)


@pytest.mark.slow  # cross-layer integration: full tier only, keeps `fast` <1min
def test_checkpoint_streams_large_float_leaves(cal_dir, tmp_path):
    from repro.checkpoint import CheckpointManager, CheckpointPolicy

    _seed_store("huffman-bytes")
    _seed_store("zfp")
    rng = np.random.default_rng(9)
    tree = {
        "big": rng.normal(size=(64, 64)).astype(np.float32),   # 16 KB: streams
        "small": rng.normal(size=(8, 8)).astype(np.float32),   # one-shot
        "ints": np.arange(32, dtype=np.int32),
    }
    mgr = CheckpointManager(
        tmp_path / "ckpt",
        policy=CheckpointPolicy(stream_threshold=8 << 10),
    )
    manifest = mgr.save(0, tree)
    leaves = manifest["leaves"]
    assert leaves["big"].get("stream") is True
    assert "window" in leaves["big"]
    assert leaves["small"].get("stream") is None
    restored, _ = mgr.restore(0)
    # big leaf is below the lossless_small elem cutoff -> huffman, exact
    np.testing.assert_array_equal(restored["big"], tree["big"])
    np.testing.assert_array_equal(restored["ints"], tree["ints"])


def test_checkpoint_default_policy_streams_nothing_small(cal_dir, tmp_path):
    from repro.checkpoint import CheckpointManager

    rng = np.random.default_rng(10)
    tree = {"w": rng.normal(size=(64, 64)).astype(np.float32)}
    mgr = CheckpointManager(tmp_path / "ckpt")
    manifest = mgr.save(0, tree)
    assert manifest["leaves"]["w"].get("stream") is None
