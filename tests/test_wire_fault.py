"""Wire-protocol fault injection (satellite: fault tier).

Kills real client *processes* mid-request (half a frame on the wire) and
mid-response (request sent, peer gone before the reply lands) and asserts
the server's containment contract: the dead client's connection is
reclaimed, only *its* request is lost, every other connection keeps
streaming — with the accounting (``reclaimed`` / ``torn_frames`` /
``send_failures``) to prove it.

The ``slow``-marked soak drives N concurrent clients with mixed-priority
traffic and requires socket-path results byte-identical to the in-process
:class:`ReductionService` API.
"""

import socket
import struct
import subprocess
import sys
import threading
import time
import zlib

import numpy as np
import pytest

from repro.serving import protocol as P
from repro.serving.client import ReductionClient
from repro.serving.server import ReductionServer
from repro.serving.service import ReductionService

TIMEOUT = 30.0

# Standalone client bodies (run via `python -c`): frames are built with raw
# struct+zlib so the subprocess never imports repro (or jax) — the kill
# lands within milliseconds of launch, while the server is mid-read or
# mid-compute, not during a 10-second interpreter warm-up.
_PREAMBLE = """
import os, socket, struct, sys, zlib
path = sys.argv[1]
sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
sock.connect(path)
def frame(opcode, rid, payload=b"", tenant=b"fault"):
    hdr = struct.pack("<4sHHQHHI", b"HPRW", 1, opcode, rid,
                      len(tenant), 0, zlib.crc32(payload) & 0xFFFFFFFF)
    body = hdr + tenant + payload
    return struct.pack("<I", len(body)) + body
"""

# dies after half a frame: the server is left holding a torn read
_KILL_MID_REQUEST = _PREAMBLE + """
blob = frame(0x01, 1, b"x" * 4096)
sock.sendall(blob[: len(blob) // 2])
os._exit(1)
"""

# dies after a *complete* request, before reading the response: the server
# computes an answer for a peer that no longer exists
_KILL_MID_RESPONSE = _PREAMBLE + """
sock.sendall(frame(0x01, 1, b"y" * 4096))
os._exit(1)
"""

# well-behaved: one ping round-trip, exit 0 (sanity for the harness)
_PING_OK = _PREAMBLE + """
sock.sendall(frame(0x01, 7, b"ok"))
n = struct.unpack("<I", sock.recv(4))[0]
got = b""
while len(got) < n:
    got += sock.recv(n - len(got))
assert got[6:8] == struct.pack("<H", 0x80), got[:24]
os._exit(0)
"""


@pytest.fixture(scope="module")
def server():
    with ReductionServer(max_queue=32, batch_window=0.002) as srv:
        yield srv


def _run_client(body: str, server, expect_rc: int | None = None):
    proc = subprocess.run(
        [sys.executable, "-c", body, server.unix_address],
        capture_output=True, text=True, timeout=TIMEOUT,
    )
    if expect_rc is not None:
        assert proc.returncode == expect_rc, proc.stderr
    return proc


def _wait_stat(fn, target, timeout=TIMEOUT):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        v = fn()
        if v >= target:
            return v
        time.sleep(0.01)
    raise AssertionError(f"stat never reached {target}: last {fn()}")


@pytest.mark.subprocess
def test_harness_sanity_wellbehaved_client(server):
    _run_client(_PING_OK, server, expect_rc=0)


@pytest.mark.subprocess
def test_client_killed_mid_request_is_contained(server):
    before = server.stats()
    # a bystander with an open connection through the whole incident
    with ReductionClient(server.unix_address, timeout=TIMEOUT) as bystander:
        assert bystander.ping(b"pre") == b"pre"
        _run_client(_KILL_MID_REQUEST, server, expect_rc=1)
        # server notices the torn frame and reclaims exactly that peer
        _wait_stat(lambda: server.stats()["torn_frames"],
                   before["torn_frames"] + 1)
        _wait_stat(lambda: server.stats()["reclaimed"],
                   before["reclaimed"] + 1)
        # the bystander's connection never blinked
        assert bystander.ping(b"post") == b"post"
        assert bystander.client_stats()["reconnects"] == 1  # initial only
        assert server.service.stats().connections["open"] >= 1  # bystander
    after = server.stats()
    # the torn frame produced no request dispatch and no response
    assert after["requests"] == before["requests"] + 2  # bystander pings


@pytest.mark.subprocess
def test_client_killed_mid_response_fails_only_that_request(server):
    before = server.stats()
    with ReductionClient(server.unix_address, timeout=TIMEOUT) as bystander:
        assert bystander.ping(b"pre") == b"pre"
        _run_client(_KILL_MID_RESPONSE, server, expect_rc=1)
        # the request WAS dispatched; its response either hit a dead socket
        # (send_failures) or drained into a buffer nobody will read —
        # either way the connection is reclaimed and nobody else pays
        _wait_stat(lambda: server.stats()["requests"],
                   before["requests"] + 2)
        _wait_stat(lambda: server.stats()["reclaimed"],
                   before["reclaimed"] + 1)
        assert bystander.ping(b"post") == b"post"
        assert bystander.client_stats()["retries"] == 0
    after = server.stats()
    assert after["send_failures"] >= before["send_failures"]


@pytest.mark.subprocess
def test_kill_storm_then_full_service(server):
    """A burst of dying clients must leave the server fully functional."""
    before = server.stats()
    procs = [
        subprocess.Popen(
            [sys.executable, "-c",
             _KILL_MID_REQUEST if i % 2 else _KILL_MID_RESPONSE,
             server.unix_address],
            stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
        for i in range(6)
    ]
    for p in procs:
        p.wait(timeout=TIMEOUT)
    _wait_stat(lambda: server.stats()["reclaimed"], before["reclaimed"] + 6)
    with ReductionClient(server.unix_address, timeout=TIMEOUT) as cli:
        rng = np.random.default_rng(3)
        tree = {"w": rng.normal(size=(48, 48)).astype(np.float32)}
        comp, _ = cli.compress(tree, method="zfp", tolerance=1e-3)
        out = cli.decompress(comp)
        ref = server.service.decompress(
            comp, {"w": np.empty_like(tree["w"])}
        )
        assert np.asarray(out["w"]).tobytes() == np.asarray(ref["w"]).tobytes()
    assert server.stats()["open_connections"] <= 1


@pytest.mark.slow
def test_soak_n_clients_mixed_priorities_byte_identical():
    """N concurrent socket clients vs the in-process API: byte-identity.

    Mixed traffic — bulk compress/decompress and stream decodes racing
    interactive KV fetches — through one server; every socket result must
    equal the in-process ``ReductionService`` answer bit for bit.
    """
    rng = np.random.default_rng(7)
    svc = ReductionService(max_queue=64, batch_window=0.004)
    n_clients, n_iter = 4, 5
    failures: list[str] = []
    with ReductionServer(svc) as srv:
        # park one session up front so interactive fetches have a target
        kv_ref = {"k": rng.normal(size=(32, 16)).astype(np.float32)}
        # KV sessions are tenant-scoped: park the same payload under every
        # worker tenant (park is deterministic → identical bytes)
        for wid in range(n_clients):
            svc.park_kv("soak", kv_ref, tenant=f"w{wid}")

        def blob(v):  # parked buffers mix Compressed and passthrough arrays
            return (v.to_bytes() if hasattr(v, "to_bytes")
                    else np.asarray(v).tobytes())

        fetched_ref = {k: blob(v)
                       for k, v in svc.fetch_kv("soak", tenant="w0").items()}
        stream_src, _ = svc.compress_stream(
            rng.normal(size=(8, 64)).astype(np.float32), "zfp",
            tolerance=1e-3, chunk_size=2, window=2,
        )
        stream_ref, _ = svc.decompress_stream(stream_src)

        def worker(wid: int):
            try:
                cli = ReductionClient(srv.unix_address, tenant=f"w{wid}",
                                      timeout=TIMEOUT)
                w_rng = np.random.default_rng(100 + wid)
                with cli:
                    for it in range(n_iter):
                        tree = {
                            f"p{wid}/{it}": w_rng.normal(
                                size=(24, 24)).astype(np.float32),
                        }
                        comp, _ = cli.compress(tree, method="zfp",
                                               tolerance=1e-3)
                        ref, _ = svc.compress(
                            tree,
                            lambda k, a: ("zfp", {"tolerance": 1e-3}),
                        )
                        for k in tree:
                            if comp[k].to_bytes() != ref[k].to_bytes():
                                failures.append(f"compress {k}")
                        out = cli.decompress(comp)
                        ref_out = svc.decompress(
                            ref, {k: np.empty_like(v)
                                  for k, v in tree.items()},
                        )
                        for k in tree:
                            if (np.asarray(out[k]).tobytes()
                                    != np.asarray(ref_out[k]).tobytes()):
                                failures.append(f"decompress {k}")
                        # interactive lane, racing the bulk work above
                        fetched = cli.fetch_kv("soak")
                        for k, ref_blob in fetched_ref.items():
                            if blob(fetched[k]) != ref_blob:
                                failures.append(f"fetch_kv {k}")
                        arr, _ = cli.decompress_stream(stream_src)
                        if arr.tobytes() != stream_ref.tobytes():
                            failures.append("stream")
            except Exception as e:  # pragma: no cover - diagnostic
                failures.append(f"worker {wid}: {type(e).__name__}: {e}")

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
        assert failures == []
        st = svc.stats()
        # both priority classes actually dispatched during the soak
        assert st.priorities["interactive"]["dispatched"] >= n_clients * n_iter
        assert st.priorities["bulk"]["dispatched"] > 0
        assert st.connections["frames_rx"] >= n_clients * n_iter * 4
    svc.close()
