"""Wire-protocol frame fuzzing (satellite: fuzz tier).

Every mutation of a valid frame — truncation, bit flips, an oversized or
undersized length prefix, bad magic/version/opcode, a tampered crc — must
raise a typed :class:`ProtocolError` naming the offending field, and a
server fed such garbage must answer (or hang up) without ever crashing its
accept/read loops or corrupting service for well-behaved connections.

Runs in `scripts/check.sh fast`: no subprocesses, no model weights — one
small in-process server shared module-wide.
"""

import random
import socket
import struct

import numpy as np
import pytest

from repro.serving import protocol as P
from repro.serving.client import ReductionClient
from repro.serving.server import ReductionServer

TIMEOUT = 30.0  # generous socket timeout: "never hang" is the assertion


@pytest.fixture(scope="module")
def server():
    with ReductionServer(max_queue=16, batch_window=0.002) as srv:
        yield srv


def _raw_conn(server):
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.settimeout(TIMEOUT)
    sock.connect(server.unix_address)
    return sock


def _valid_frame(payload=b"hello", rid=7, tenant="fuzz"):
    return P.encode_frame(P.OP_PING, rid, payload, tenant=tenant)


# ---------------------------------------------------------------------------
# parse_frame: pure-function field validation
# ---------------------------------------------------------------------------


def test_frame_roundtrip_preserves_fields():
    blob = P.encode_frame(P.OP_COMPRESS, 42, b"xyz", tenant="t0", flags=0)
    (n,) = struct.unpack_from("<I", blob)
    assert n == len(blob) - 4
    f = P.parse_frame(blob[4:])
    assert (f.opcode, f.request_id, f.payload, f.tenant, f.flags) == (
        P.OP_COMPRESS, 42, b"xyz", "t0", 0,
    )
    assert f.opcode_name == "compress"


@pytest.mark.parametrize(
    "mutate,field",
    [
        (lambda b: b[:10], "truncated"),                       # torn header
        (lambda b: b"JUNK" + b[4:], "magic"),
        (lambda b: b[:4] + struct.pack("<H", 99) + b[6:], "version"),
        (lambda b: b[:6] + struct.pack("<H", 0x7F) + b[8:], "opcode"),
        # tenant_len pointing past the end of the frame
        (lambda b: b[:16] + struct.pack("<H", 0xFFFF) + b[18:], "tenant"),
        # flip a payload bit: recorded crc32 no longer matches
        (lambda b: b[:-1] + bytes([b[-1] ^ 0x01]), "crc32"),
        # tamper the recorded crc itself
        (lambda b: b[:20] + struct.pack("<I", 0xDEADBEEF) + b[24:], "crc32"),
    ],
)
def test_parse_frame_names_the_field(mutate, field):
    body = _valid_frame()[4:]
    with pytest.raises(P.ProtocolError) as ei:
        P.parse_frame(mutate(bytes(body)))
    assert ei.value.field == field
    assert f"[field={field}]" in str(ei.value)


def test_parse_frame_rejects_invalid_utf8_tenant():
    body = bytearray(_valid_frame(tenant="abcd")[4:])
    body[P.HEADER_BYTES] = 0xFF  # lone continuation byte: invalid utf-8
    with pytest.raises(P.ProtocolError) as ei:
        P.parse_frame(bytes(body))
    assert ei.value.field == "tenant"


def test_parse_frame_attaches_request_id_after_header():
    # post-header failures carry the (trustworthy) request id so the server
    # can address its OP_ERROR response
    body = bytearray(_valid_frame(rid=123)[4:])
    body[-1] ^= 0x10
    with pytest.raises(P.ProtocolError) as ei:
        P.parse_frame(bytes(body))
    assert getattr(ei.value, "request_id", None) == 123


def test_length_prefix_bounds():
    with pytest.raises(P.ProtocolError) as ei:
        P.read_length_prefix(struct.pack("<I", P.HEADER_BYTES - 1))
    assert ei.value.field == "length"
    with pytest.raises(P.ProtocolError) as ei:
        P.read_length_prefix(struct.pack("<I", 0xFFFFFFFF), max_frame=1 << 20)
    assert ei.value.field == "length"
    with pytest.raises(P.ProtocolError) as ei:
        P.read_length_prefix(b"\x01\x02")
    assert ei.value.field == "truncated"
    assert P.read_length_prefix(struct.pack("<I", 64)) == 64


def test_parse_frame_fuzz_never_hangs_or_misparses():
    """Random mutations: typed ProtocolError or a clean parse — nothing else.

    Bit flips in crc-uncovered header fields (request_id, flags) may yield a
    *valid* frame with different values; that is fine — the contract is "no
    hang, no crash, no exception other than ProtocolError".
    """
    rng = random.Random(0)
    base = _valid_frame(payload=b"p" * 64, tenant="tenant-x")[4:]
    for _ in range(500):
        b = bytearray(base)
        op = rng.randrange(3)
        if op == 0:  # truncate
            b = b[: rng.randrange(len(b))]
        elif op == 1:  # bit flips
            for _ in range(rng.randrange(1, 4)):
                i = rng.randrange(len(b))
                b[i] ^= 1 << rng.randrange(8)
        else:  # splice random garbage
            i = rng.randrange(len(b))
            b[i : i + rng.randrange(1, 9)] = rng.randbytes(rng.randrange(9))
        try:
            frame = P.parse_frame(bytes(b))
        except P.ProtocolError as e:
            assert e.field  # typed, field-attributed
        else:
            assert isinstance(frame, P.Frame)


def test_loads_payload_fuzz_is_typed():
    comp_payload = P.dumps_payload(
        {"a": np.arange(16, dtype=np.float32), "raw": b"\x00\x01"},
        {"k": 1},
    )
    # round-trip sanity first
    flat, extra = P.loads_payload(comp_payload)
    assert extra == {"k": 1}
    np.testing.assert_array_equal(flat["a"], np.arange(16, dtype=np.float32))
    rng = random.Random(1)
    for _ in range(300):
        b = bytearray(comp_payload)
        if rng.random() < 0.5:
            b = b[: rng.randrange(len(b))]
        else:
            for _ in range(rng.randrange(1, 4)):
                i = rng.randrange(len(b))
                b[i] ^= 1 << rng.randrange(8)
        try:
            P.loads_payload(bytes(b))
        except P.ProtocolError as e:
            assert e.field == "payload"


def test_error_payload_roundtrip_does_not_double_field_suffix():
    e = P.ProtocolError("boom", field="crc32")
    payload = P.error_payload(e)
    with pytest.raises(P.ProtocolError) as ei:
        P.raise_error_payload(payload)
    assert str(ei.value).count("[field=crc32]") == 1
    assert ei.value.field == "crc32"


# ---------------------------------------------------------------------------
# server loop survival under garbage
# ---------------------------------------------------------------------------


def test_server_rejects_oversized_length_prefix_and_hangs_up(server):
    sock = _raw_conn(server)
    try:
        sock.sendall(struct.pack("<I", 0xFFFFFFFF))
        frame = P.recv_frame(sock, max_frame=server.max_frame)
        assert frame is not None and frame.opcode == P.OP_ERROR
        with pytest.raises(P.ProtocolError) as ei:
            P.raise_error_payload(frame.payload)
        assert ei.value.field == "length"
        # framing is unrecoverable: server closes the connection
        assert P.recv_frame(sock, max_frame=server.max_frame) is None
    finally:
        sock.close()
    _assert_still_serving(server)


def test_server_survives_bad_magic_then_serves_fresh_connection(server):
    sock = _raw_conn(server)
    try:
        junk = b"GET / HTTP/1.1\r\n\r\n"  # wrong protocol entirely
        sock.sendall(struct.pack("<I", max(len(junk), P.HEADER_BYTES)))
        sock.sendall(junk.ljust(P.HEADER_BYTES, b"\x00"))
        frame = P.recv_frame(sock, max_frame=server.max_frame)
        assert frame is not None and frame.opcode == P.OP_ERROR
    finally:
        sock.close()
    _assert_still_serving(server)


def test_server_reports_crc_error_and_keeps_connection(server):
    sock = _raw_conn(server)
    try:
        blob = bytearray(_valid_frame(payload=b"x" * 32, rid=5))
        blob[-1] ^= 0x40  # payload bit flip → crc mismatch
        sock.sendall(bytes(blob))
        frame = P.recv_frame(sock, max_frame=server.max_frame)
        assert frame is not None and frame.opcode == P.OP_ERROR
        assert frame.request_id == 5  # addressed to the mangled request
        with pytest.raises(P.ProtocolError) as ei:
            P.raise_error_payload(frame.payload)
        assert ei.value.field == "crc32"
        # frame boundary was intact → SAME connection keeps working
        sock.sendall(_valid_frame(payload=b"alive", rid=6))
        frame = P.recv_frame(sock, max_frame=server.max_frame)
        assert frame is not None and frame.opcode == P.OP_OK
        assert (frame.request_id, frame.payload) == (6, b"alive")
    finally:
        sock.close()


def test_server_counts_protocol_errors_in_stats(server):
    before = server.service.stats().connections["protocol_errors"]
    sock = _raw_conn(server)
    try:
        blob = bytearray(_valid_frame(rid=9))
        blob[-1] ^= 0x01
        sock.sendall(bytes(blob))
        assert P.recv_frame(sock).opcode == P.OP_ERROR
    finally:
        sock.close()
    after = server.service.stats().connections["protocol_errors"]
    assert after == before + 1
    assert server.stats()["protocol_errors"] >= 1


def test_server_fuzzed_frames_never_wedge_the_loop(server):
    """Fire 60 mutated frames (fresh connection each — some mutations are
    framing-fatal) and require a typed error or a hangup within the socket
    timeout every time; the server must still serve afterwards."""
    rng = random.Random(2)
    base = _valid_frame(payload=b"q" * 48, tenant="fz")
    outcomes = {"error_frame": 0, "hangup": 0, "ok": 0}
    for _ in range(60):
        b = bytearray(base)
        op = rng.randrange(3)
        if op == 0:
            b = b[:4] + b[4 : 4 + rng.randrange(len(b) - 4)]
            # fix the prefix so the server waits for exactly what we send,
            # then close → torn-frame path
            b[0:4] = struct.pack("<I", max(len(b) - 4 + 1, P.HEADER_BYTES))
        elif op == 1:
            i = rng.randrange(4, len(b))
            b[i] ^= 1 << rng.randrange(8)
        else:
            b[0:4] = struct.pack("<I", rng.choice([0, 1, 23, 0x7FFFFFFF]))
        sock = _raw_conn(server)
        try:
            sock.sendall(bytes(b))
            sock.shutdown(socket.SHUT_WR)
            frame = P.recv_frame(sock, max_frame=server.max_frame)
            if frame is None:
                outcomes["hangup"] += 1
            elif frame.opcode == P.OP_ERROR:
                outcomes["error_frame"] += 1
            else:
                outcomes["ok"] += 1  # mutation hit a crc-uncovered field
        except P.ProtocolError:
            outcomes["hangup"] += 1  # server died mid-response? no — torn
        finally:
            sock.close()
    assert outcomes["error_frame"] > 0  # fuzzer did reach validation
    _assert_still_serving(server)
    # every fuzz connection was reclaimed
    deadline_stats = server.stats()
    assert deadline_stats["open_connections"] <= 1


def test_response_opcode_as_request_is_rejected(server):
    sock = _raw_conn(server)
    try:
        sock.sendall(P.encode_frame(P.OP_OK, 11, b"", tenant="fz"))
        frame = P.recv_frame(sock)
        assert frame.opcode == P.OP_ERROR
        with pytest.raises(P.ProtocolError) as ei:
            P.raise_error_payload(frame.payload)
        assert ei.value.field == "opcode"
    finally:
        sock.close()


def _assert_still_serving(server):
    with ReductionClient(server.unix_address, timeout=TIMEOUT) as cli:
        assert cli.ping(b"ok?") == b"ok?"
