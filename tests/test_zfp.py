"""ZFP-X: transform inversion, rate behaviour, roundtrip error decay."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import zfp
from conftest import smooth_field_3d


def test_lift_near_inverse(rng):
    v = rng.integers(-(2**29), 2**29, (1000, 4)).astype(np.int32)
    f = zfp.fwd_lift_vec(jnp.asarray(v))
    r = np.asarray(zfp.inv_lift_vec(f))
    # zfp's lift drops low bits by design; error is a few ULPs at 2^29 scale
    assert np.abs(r - v).max() <= 4


def test_negabinary_roundtrip(rng):
    q = rng.integers(-(2**31), 2**31 - 1, 10000).astype(np.int32)
    u = zfp.int_to_negabinary(jnp.asarray(q))
    out = np.asarray(zfp.negabinary_to_int(u))
    assert (out == q).all()


def test_bitplane_pack_roundtrip(rng):
    u = rng.integers(0, 2**32, (50, 64), dtype=np.uint32)
    for rate in (1, 7, 16, 32):
        words = zfp.pack_bitplanes(jnp.asarray(u), rate)
        out = np.asarray(zfp.unpack_bitplanes(words, rate, 64))
        mask = np.uint64(0xFFFFFFFF) << np.uint64(32 - rate)
        expect = (u.astype(np.uint64) & mask).astype(np.uint32)
        assert (out == expect).all(), rate


def test_error_decays_with_rate():
    data = smooth_field_3d(32)
    errs = []
    for rate in (4, 8, 16, 32):
        z = zfp.compress(jnp.asarray(data), rate=rate)
        out = np.asarray(zfp.decompress(z))
        errs.append(np.abs(out - data).max())
    assert errs[-1] < 1e-6  # near-lossless at rate 32
    for a, b in zip(errs, errs[1:]):
        assert b <= a * 1.01  # monotone (within float noise)


def test_fixed_rate_size():
    data = smooth_field_3d(32)
    z = zfp.compress(jnp.asarray(data), rate=8)
    n_blocks = (32 // 4) ** 3
    assert z.payload.shape == (n_blocks, zfp.words_per_block(64, 8))
    assert z.emax.shape == (n_blocks,)


@settings(max_examples=20, deadline=None)
@given(
    st.integers(1, 3),
    st.sampled_from([4, 8, 16, 32]),
    st.integers(0, 2**31),
)
def test_roundtrip_property(dims, rate, seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(3, 17, dims))
    scale = 10.0 ** rng.integers(-8, 8)
    data = (rng.normal(size=shape) * scale).astype(np.float32)
    z = zfp.compress(jnp.asarray(data), rate=rate)
    out = np.asarray(zfp.decompress(z))
    assert out.shape == data.shape
    vrange = np.abs(data).max() + 1e-30
    rel = np.abs(out - data).max() / vrange
    # Negabinary truncation + inverse-transform gain: worst-case relative
    # error ≈ gain·2^(2-rate).  At rate 4 on adversarial (white-noise) data
    # hypothesis found rel ≈ 2.5 — the documented cost of fixed truncation
    # without zfp's group testing; real use keeps rate ≥ 8 (rel ≤ 0.5).
    bound = {4: 6.0, 8: 0.5, 16: 2e-3, 32: 5e-6}[rate]
    assert rel <= bound, (shape, rate, rel)


def test_zero_and_constant_blocks():
    for val in (0.0, 3.25, -1e-20):
        data = np.full((16, 16), val, np.float32)
        z = zfp.compress(jnp.asarray(data), rate=16)
        out = np.asarray(zfp.decompress(z))
        assert np.abs(out - data).max() <= max(abs(val) * 1e-4, 1e-30)
